//! Huber-loss regression — robust alternative to least squares on the
//! same `(O, T)` shards:
//!
//! ```text
//! f(x) = (1/b) Σ_{j,c} h_δ(⟨o_j, x_c⟩ − t_{jc}),
//! h_δ(r) = r²/2        for |r| ≤ δ,
//!          δ(|r| − δ/2) otherwise.
//! ```
//!
//! C¹ with ψ_δ(r) = clamp(r, −δ, δ) and λ_max(OᵀO/b)-smooth (|ψ′| ≤ 1),
//! so Assumptions 2–3 hold with the same constants as least squares.
//! The exact prox reuses the damped-Newton column solver with the
//! IRLS-style 0/1 curvature weights (the generalized Hessian of h_δ).

use super::newton::newton_prox_column;
use super::{data_spectral_bound, Objective};
use crate::data::Split;
use crate::linalg::Matrix;
use std::cell::RefCell;

/// One agent's Huber objective over its shard.
pub struct Huber {
    data: Split,
    delta: f64,
    lips: RefCell<Option<f64>>,
    /// Per-row clipped-residual scratch (d entries), reused every round.
    coef: RefCell<Vec<f64>>,
}

impl Huber {
    /// Wrap an agent shard with transition point `delta > 0`.
    pub fn new(data: Split, delta: f64) -> Self {
        assert!(delta > 0.0, "huber delta must be positive");
        let d = data.targets.cols();
        Self { data, delta, lips: RefCell::new(None), coef: RefCell::new(vec![0.0; d]) }
    }

    /// The transition point δ.
    pub fn delta(&self) -> f64 {
        self.delta
    }

    fn penalty(&self, r: f64) -> f64 {
        let a = r.abs();
        if a <= self.delta {
            0.5 * r * r
        } else {
            self.delta * (a - 0.5 * self.delta)
        }
    }
}

impl Objective for Huber {
    fn dims(&self) -> (usize, usize) {
        (self.data.inputs.cols(), self.data.targets.cols())
    }

    fn num_examples(&self) -> usize {
        self.data.len()
    }

    fn loss(&self, x: &Matrix) -> f64 {
        let (p, d) = self.dims();
        let b = self.num_examples();
        let mut total = 0.0;
        for j in 0..b {
            let row = self.data.inputs.row(j);
            for c in 0..d {
                let mut m = 0.0;
                for k in 0..p {
                    m += row[k] * x[(k, c)];
                }
                total += self.penalty(m - self.data.targets[(j, c)]);
            }
        }
        total / b as f64
    }

    fn grad(&self, x: &Matrix, out: &mut Matrix) {
        self.grad_rows(x, 0, self.num_examples(), out);
    }

    /// `out = (1/rows) O_blockᵀ ψ_δ(O_block x − T_block)`.
    fn grad_rows(&self, x: &Matrix, lo: usize, hi: usize, out: &mut Matrix) {
        debug_assert!(lo < hi && hi <= self.num_examples());
        let (p, d) = self.dims();
        debug_assert_eq!(out.shape(), (p, d));
        out.fill_zero();
        let mut coef = self.coef.borrow_mut();
        for j in lo..hi {
            let row = self.data.inputs.row(j);
            for c in 0..d {
                let mut m = 0.0;
                for k in 0..p {
                    m += row[k] * x[(k, c)];
                }
                let r = m - self.data.targets[(j, c)];
                coef[c] = r.clamp(-self.delta, self.delta);
            }
            for k in 0..p {
                let o_jk = row[k];
                let orow = out.row_mut(k);
                for c in 0..d {
                    orow[c] += o_jk * coef[c];
                }
            }
        }
        out.scale(1.0 / (hi - lo) as f64);
    }

    /// Mean Huber penalty of the held-out residuals — the loss this
    /// objective actually optimizes, evaluated on the test split
    /// (plain MSE would re-weight exactly the outliers Huber is chosen
    /// to discount).
    fn test_loss(&self, x: &Matrix, test: &Split) -> f64 {
        let (p, d) = self.dims();
        let n = test.len();
        if n == 0 {
            return 0.0;
        }
        let mut total = 0.0;
        for j in 0..n {
            let row = test.inputs.row(j);
            for c in 0..d {
                let mut m = 0.0;
                for k in 0..p {
                    m += row[k] * x[(k, c)];
                }
                total += self.penalty(m - test.targets[(j, c)]);
            }
        }
        total / n as f64
    }

    fn prox_exact(&self, z: &Matrix, y: &Matrix, rho: f64) -> Matrix {
        let (p, d) = self.dims();
        let b = self.num_examples();
        let delta = self.delta;
        let mut out = Matrix::zeros(p, d);
        for c in 0..d {
            let ts: Vec<f64> = (0..b).map(|j| self.data.targets[(j, c)]).collect();
            let zc: Vec<f64> = (0..p).map(|k| z[(k, c)]).collect();
            let uc: Vec<f64> = (0..p).map(|k| y[(k, c)]).collect();
            let v = newton_prox_column(
                &self.data.inputs,
                &ts,
                &|m, t| {
                    let r = m - t;
                    if r.abs() <= delta {
                        (0.5 * r * r, r, 1.0)
                    } else {
                        (delta * (r.abs() - 0.5 * delta), delta * r.signum(), 0.0)
                    }
                },
                0.0,
                rho,
                &zc,
                &uc,
                zc.clone(),
            );
            for k in 0..p {
                out[(k, c)] = v[k];
            }
        }
        out
    }

    fn lipschitz(&self) -> f64 {
        if let Some(l) = *self.lips.borrow() {
            return l;
        }
        let l = data_spectral_bound(&self.data.inputs);
        *self.lips.borrow_mut() = Some(l);
        l
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic_small;
    use crate::rng::{Rng, Xoshiro256pp};

    fn toy(seed: u64) -> Huber {
        Huber::new(synthetic_small(80, 8, 0.1, seed).train, 1.0)
    }

    #[test]
    fn quadratic_region_matches_least_squares_gradient() {
        // With a huge delta every residual is in the quadratic region —
        // Huber degenerates to least squares exactly.
        let ds = synthetic_small(60, 6, 0.1, 87);
        let hub = Huber::new(ds.train.clone(), 1e9);
        let ls = super::super::LeastSquares::new(ds.train);
        let x = Matrix::full(3, 1, 0.3);
        assert!((hub.loss(&x) - ls.loss(&x)).abs() < 1e-9);
        let mut gh = Matrix::zeros(3, 1);
        let mut gl = Matrix::zeros(3, 1);
        hub.grad(&x, &mut gh);
        ls.grad(&x, &mut gl);
        assert!(gh.max_abs_diff(&gl) < 1e-10);
    }

    #[test]
    fn gradient_is_bounded_by_delta() {
        // Far from the data the clipped residual caps the gradient.
        let obj = toy(88);
        let x = Matrix::full(3, 1, 1e6);
        let mut g = Matrix::zeros(3, 1);
        obj.grad(&x, &mut g);
        // |g_k| ≤ δ · mean_j |o_jk| ≤ δ · max row magnitude.
        let bound = obj.delta()
            * obj
                .data
                .inputs
                .as_slice()
                .iter()
                .fold(0.0_f64, |m, &v| m.max(v.abs()))
            * obj.dims().1 as f64;
        assert!(g.max_abs() <= bound, "{} vs {bound}", g.max_abs());
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let obj = toy(89);
        let mut rng = Xoshiro256pp::seed_from_u64(90);
        let (p, d) = obj.dims();
        let x = Matrix::from_vec(p, d, (0..p * d).map(|_| rng.normal()).collect()).unwrap();
        let mut g = Matrix::zeros(p, d);
        obj.grad(&x, &mut g);
        let eps = 1e-6;
        for i in 0..p {
            for j in 0..d {
                let mut xp = x.clone();
                xp[(i, j)] += eps;
                let mut xm = x.clone();
                xm[(i, j)] -= eps;
                let fd = (obj.loss(&xp) - obj.loss(&xm)) / (2.0 * eps);
                assert!((fd - g[(i, j)]).abs() < 1e-5, "({i},{j}): {fd} vs {}", g[(i, j)]);
            }
        }
    }

    #[test]
    fn prox_satisfies_optimality() {
        let obj = toy(91);
        let (p, d) = obj.dims();
        let z = Matrix::full(p, d, 0.5);
        let y = Matrix::full(p, d, -0.2);
        let rho = 0.9;
        let v = obj.prox_exact(&z, &y, rho);
        let mut g = Matrix::zeros(p, d);
        obj.grad(&v, &mut g);
        let mut kkt = g;
        kkt.add_scaled(rho, &v);
        kkt.add_scaled(-rho, &z);
        kkt -= &y;
        assert!(kkt.max_abs() < 1e-7, "KKT residual {}", kkt.max_abs());
    }
}
