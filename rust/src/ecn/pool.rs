//! Per-agent ECN pool on the simulated clock (Alg. 1 steps 13–20 /
//! Alg. 2 steps 12–19).

use crate::coding::GradientCode;
use crate::data::{partition_to_ecns, BatchCursor, EcnPartition, Split};
use crate::error::{Error, Result};
use crate::latency::{LatencySpec, NodeLatency};
use crate::linalg::Matrix;
use crate::problem::{LeastSquares, Objective};
use crate::rng::{Rng, Xoshiro256pp};
use crate::runtime::Engine;
use std::rc::Rc;

/// Baseline ECN compute-cost parameters plus straggler injection.
///
/// Response time of a non-straggling ECN processing `rows` examples in
/// the default (Uniform) latency regime:
/// `base + per_row·rows + Exp(jitter_mean)`. Straggling ECNs add the
/// paper's maximum delay parameter ε on top. `straggler_count` ECNs per
/// round are chosen uniformly at random to straggle.
///
/// Richer service-time regimes (heavy tails, persistently slow nodes,
/// fail-stop faults) reuse these cost parameters through
/// [`crate::latency::LatencySpec`] / [`crate::latency::LatencyModel`].
#[derive(Clone, Debug)]
pub struct ResponseModel {
    pub base: f64,
    pub per_row: f64,
    pub jitter_mean: f64,
    /// The paper's ε: extra delay a straggler adds (swept in Fig. 3e).
    pub straggler_delay: f64,
    /// Actual number of straggling ECNs per round (paper: S_i = 1).
    pub straggler_count: usize,
}

impl Default for ResponseModel {
    fn default() -> Self {
        Self {
            base: 1e-5,
            per_row: 1e-6,
            jitter_mean: 2e-5,
            straggler_delay: 5e-3,
            straggler_count: 0,
        }
    }
}

/// Result of one coded gradient round at an agent.
#[derive(Clone, Debug)]
pub struct RoundResult {
    /// Decoded mini-batch gradient `G_i(x; ξ)` (already divided by K).
    pub grad: Matrix,
    /// Simulated time until the decode succeeded (the iteration's
    /// response time).
    pub response_time: f64,
    /// Number of ECN responses consumed by the decoder.
    pub responses_used: usize,
    /// Whether any used response came from a straggler (i.e., the round
    /// had to wait out a straggler delay).
    pub waited_for_straggler: bool,
}

/// Outcome of a timeout-aware gradient round
/// ([`EcnPool::gradient_round_at`] /
/// [`GradientBackend::round`](super::GradientBackend::round)): either a
/// decoded gradient or a deadline expiry (fail-stop faults /
/// pathological tails kept the round undecodable for `deadline` seconds
/// and the agent gave it up).
#[derive(Clone, Debug)]
pub enum RoundOutcome {
    /// The round decoded; proceed with the ADMM update.
    Decoded(RoundResult),
    /// No decodable subset of live arrivals landed before the deadline:
    /// the agent abandons this round's gradient, charging the full
    /// `elapsed = deadline` wait.
    TimedOut { elapsed: f64 },
}

/// One ECN's drawn response for a round: the modeled arrival time (on
/// the simulated clock; `f64::INFINITY` for a fail-stopped node), the
/// ECN index and whether the ε-injection straggler delay was applied.
///
/// Produced in arrival order by [`EcnPool::draw_arrivals`]; both the
/// simulated decode loop and the real-thread backend consume the same
/// draws, which is what keeps the two backends byte-identical.
#[derive(Clone, Copy, Debug)]
pub struct ArrivalDraw {
    /// Modeled response time (seconds on the simulated clock).
    pub t: f64,
    /// Responding ECN index.
    pub ecn: usize,
    /// Whether this response paid the straggler delay ε.
    pub straggler: bool,
}

/// One agent's pool of K ECNs over the agent's local [`Objective`].
pub struct EcnPool {
    agent: usize,
    objective: Rc<dyn Objective>,
    code: Box<dyn GradientCode>,
    partitions: Vec<EcnPartition>,
    cursors: Vec<BatchCursor>,
    response: ResponseModel,
    /// Per-ECN latency state (service-time model, clock, fault window)
    /// built from the run's [`LatencySpec`].
    nodes: Vec<NodeLatency>,
    /// Per-round decode deadline (None = wait indefinitely).
    deadline: Option<f64>,
    rng: Xoshiro256pp,
    /// Scratch: per-partition gradient buffers, reused every round
    /// (§Perf: the hot loop allocates nothing after warm-up).
    part_grads: Vec<Matrix>,
    /// Which scratch buffers are valid for the current round.
    part_done: Vec<bool>,
    /// Scratch: the round's arrived coded messages, reused across
    /// rounds (only the first `used` slots of a round are live; decode
    /// sees exactly that prefix).
    arrived: Vec<(usize, Matrix)>,
}

impl EcnPool {
    /// Build a pool in the default (Uniform / paper-baseline) latency
    /// regime. `per_partition_batch_rows` is the per-partition
    /// batch size: `M/K` for sI-ADMM, `M̄/K` for csI-ADMM (so that each
    /// coded ECN computes `(S+1)·M̄/K` rows — Alg. 2 step 7).
    pub fn new(
        agent: usize,
        objective: Rc<dyn Objective>,
        code: Box<dyn GradientCode>,
        per_partition_batch_rows: usize,
        response: ResponseModel,
        rng: Xoshiro256pp,
    ) -> Result<Self> {
        Self::with_latency(
            agent,
            objective,
            code,
            per_partition_batch_rows,
            response,
            &LatencySpec::default(),
            rng,
        )
    }

    /// Build a pool under an explicit latency scenario (service-time
    /// regime, per-ECN clocks, fail-stop faults, decode deadline).
    pub fn with_latency(
        agent: usize,
        objective: Rc<dyn Objective>,
        code: Box<dyn GradientCode>,
        per_partition_batch_rows: usize,
        response: ResponseModel,
        latency: &LatencySpec,
        rng: Xoshiro256pp,
    ) -> Result<Self> {
        let k = code.k();
        let partitions = partition_to_ecns(agent, objective.num_examples(), k)?;
        let cursors = partitions
            .iter()
            .map(|p| BatchCursor::new(p.len(), per_partition_batch_rows))
            .collect::<Result<Vec<_>>>()?;
        let nodes = latency.build_nodes(agent, k, &response);
        let part_grads = vec![];
        let part_done = vec![false; k];
        Ok(Self {
            agent,
            objective,
            code,
            partitions,
            cursors,
            response,
            nodes,
            deadline: latency.deadline,
            rng,
            part_grads,
            part_done,
            arrived: vec![],
        })
    }

    /// Convenience: a pool over the paper's least-squares loss on an
    /// owned shard (tests, examples).
    pub fn least_squares(
        agent: usize,
        data: Split,
        code: Box<dyn GradientCode>,
        per_partition_batch_rows: usize,
        response: ResponseModel,
        rng: Xoshiro256pp,
    ) -> Result<Self> {
        Self::new(
            agent,
            Rc::new(LeastSquares::new(data)),
            code,
            per_partition_batch_rows,
            response,
            rng,
        )
    }

    /// Owning agent id.
    pub fn agent(&self) -> usize {
        self.agent
    }

    /// The pool's coding scheme.
    pub fn code(&self) -> &dyn GradientCode {
        self.code.as_ref()
    }

    /// Effective mini-batch rows per iteration (distinct examples):
    /// `K · per_partition_batch_rows`.
    pub fn effective_batch(&self) -> usize {
        self.code.k() * self.cursors[0].batch_rows()
    }

    /// Per-round decode deadline (seconds), if configured.
    pub fn deadline(&self) -> Option<f64> {
        self.deadline
    }

    /// Absolute row ranges (into the agent's shard) ECN `ecn` processes
    /// at cycle `cycle` — one `(lo, hi)` per assigned partition, in
    /// assignment order. This is the work order a real ECN worker
    /// receives from the agent each round.
    pub fn batch_ranges(&self, ecn: usize, cycle: usize) -> Vec<(usize, usize)> {
        self.code
            .assignment(ecn)
            .iter()
            .map(|&p| {
                let (blo, bhi) = self.cursors[p].batch_range(cycle);
                (self.partitions[p].lo + blo, self.partitions[p].lo + bhi)
            })
            .collect()
    }

    /// Sample this round's per-ECN response times at simulated time
    /// `now` (straggler ε-injection, service-time regime, clocks,
    /// fail-stop windows), returning them in arrival order (NaN-safe
    /// `total_cmp`, ECN-index tie-break — deterministic).
    ///
    /// This is the *only* stochastic part of a gradient round, so both
    /// backends route through it: the simulated decode loop consumes the
    /// draws directly, and [`super::ThreadedBackend`] turns the same
    /// draws into scaled real sleeps — which is what keeps the two
    /// backends' decoded bytes identical.
    pub fn draw_arrivals(&mut self, now: f64) -> Vec<ArrivalDraw> {
        let k = self.code.k();
        let stragglers: Vec<usize> = if self.response.straggler_count > 0 {
            self.rng.sample_indices(k, self.response.straggler_count.min(k))
        } else {
            vec![]
        };
        let mut arrivals: Vec<ArrivalDraw> = (0..k)
            .map(|j| {
                // Charge each ECN for the rows of *its own* assigned
                // partitions (cursors can differ per partition; do not
                // assume cursor 0's geometry).
                let rows: usize = self
                    .code
                    .assignment(j)
                    .iter()
                    .map(|&p| self.cursors[p].batch_rows())
                    .sum();
                let straggler = stragglers.contains(&j);
                let mut t = self.nodes[j].response_time(rows, now, &mut self.rng);
                if straggler {
                    t += self.response.straggler_delay;
                }
                ArrivalDraw { t, ecn: j, straggler }
            })
            .collect();
        // Arrival order. `total_cmp` is NaN-safe (a degenerate response
        // model must not panic the round); ties break on the ECN index
        // so arrival order stays deterministic.
        arrivals.sort_by(|a, b| a.t.total_cmp(&b.t).then(a.ecn.cmp(&b.ecn)));
        arrivals
    }

    /// Run one gradient round at cycle index `m = ⌊k/N⌋`:
    /// broadcast `x`, compute per-partition gradients on the selected
    /// batches, encode per ECN, simulate response times, decode from the
    /// earliest decodable prefix.
    ///
    /// Convenience wrapper over [`Self::gradient_round_at`] at simulated
    /// time 0 that treats a deadline expiry as an error — use the
    /// timeout-aware variant when fail-stop faults or deadlines are in
    /// play.
    pub fn gradient_round(
        &mut self,
        x: &Matrix,
        cycle: usize,
        engine: &mut dyn Engine,
    ) -> Result<RoundResult> {
        match self.gradient_round_at(x, cycle, 0.0, engine)? {
            RoundOutcome::Decoded(r) => Ok(r),
            RoundOutcome::TimedOut { .. } => Err(Error::Latency(format!(
                "agent {}: gradient round timed out (use gradient_round_at for \
                 timeout-aware rounds)",
                self.agent
            ))),
        }
    }

    /// Timeout-aware gradient round at simulated time `now` (drives
    /// fail-stop fault windows). The decode-deadline policy lives here:
    /// the agent proceeds as soon as any decodable subset of the
    /// fastest arrivals is in, charging only elapsed simulated time; if
    /// a deadline is configured and no decodable subset of live
    /// arrivals lands in time, the round resolves to
    /// [`RoundOutcome::TimedOut`] instead of stalling forever.
    pub fn gradient_round_at(
        &mut self,
        x: &Matrix,
        cycle: usize,
        now: f64,
        engine: &mut dyn Engine,
    ) -> Result<RoundOutcome> {
        let k = self.code.k();
        let (px, dx) = x.shape();
        // Warm-up: size the reusable per-partition gradient buffers.
        if self.part_grads.len() != k || self.part_grads[0].shape() != (px, dx) {
            self.part_grads = (0..k).map(|_| Matrix::zeros(px, dx)).collect();
        }
        // 1. Per-partition gradients (computed once even when replicated
        //    on several ECNs; the simulated clock still charges each ECN
        //    for its own compute). The objective routes least squares
        //    through the engine's zero-copy row-range kernel and other
        //    losses through their native oracle — no allocation in the
        //    steady state either way.
        for done in &mut self.part_done {
            *done = false;
        }
        for j in 0..k {
            for &p in self.code.assignment(j) {
                if !self.part_done[p] {
                    let (blo, bhi) = self.cursors[p].batch_range(cycle);
                    let lo = self.partitions[p].lo + blo;
                    let hi = self.partitions[p].lo + bhi;
                    self.objective.grad_rows_engine(
                        engine,
                        x,
                        lo,
                        hi,
                        &mut self.part_grads[p],
                    )?;
                    self.part_done[p] = true;
                }
            }
        }
        // 2. Sample response times through each node's latency state
        //    (service-time model, clock, fault window), sorted into
        //    arrival order.
        let arrivals = self.draw_arrivals(now);
        // 3. Decode from the earliest decodable prefix (paper: wait for
        //    the R-th fastest; uncoded degenerates to all K). Arrivals
        //    past the deadline — and down nodes, which "arrive" at
        //    t = ∞ — are never consumed; the list is sorted, so the
        //    first such arrival ends the wait. Encoding happens lazily
        //    per consumed arrival (pure per-ECN linear combination of
        //    the shared partition gradients, so the bytes are identical
        //    to encoding everything up front), through the scheme's
        //    allocation-free `encode_into` into slots reused across
        //    rounds.
        let r = self.code.r();
        let mut used = 0;
        let mut response_time = 0.0;
        let mut waited_for_straggler = false;
        let mut saw_unreachable = false;
        let mut decoded: Option<Matrix> = None;
        for ArrivalDraw { t, ecn: j, straggler } in arrivals {
            if !t.is_finite() || self.deadline.is_some_and(|d| t > d) {
                saw_unreachable |= !t.is_finite();
                break;
            }
            if used == self.arrived.len() {
                self.arrived.push((j, Matrix::zeros(px, dx)));
            } else {
                self.arrived[used].0 = j;
                if self.arrived[used].1.shape() != (px, dx) {
                    self.arrived[used].1 = Matrix::zeros(px, dx);
                }
            }
            self.code.encode_into(j, &self.part_grads, &mut self.arrived[used].1);
            used += 1;
            response_time = t;
            waited_for_straggler |= straggler;
            if used < r {
                continue;
            }
            match self.code.decode(&self.arrived[..used]) {
                Ok(sum) => {
                    decoded = Some(sum);
                    break;
                }
                Err(_) if used < k => continue,
                Err(e) => return Err(e),
            }
        }
        let sum = match decoded {
            Some(sum) => sum,
            None => {
                return if let Some(d) = self.deadline {
                    Ok(RoundOutcome::TimedOut { elapsed: d })
                } else if saw_unreachable {
                    Err(Error::Latency(format!(
                        "agent {}: round stalled — fail-stopped ECNs leave no decodable \
                         subset; set a [latency] deadline or use a coded scheme that \
                         tolerates the failure",
                        self.agent
                    )))
                } else {
                    Err(Error::Coding(format!("agent {}: round undecodable", self.agent)))
                };
            }
        };
        // G = (1/K) Σ_p g̃_p (Eq. 6).
        let grad = sum.scaled(1.0 / k as f64);
        Ok(RoundOutcome::Decoded(RoundResult {
            grad,
            response_time,
            responses_used: used,
            waited_for_straggler,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CyclicRepetition, FractionalRepetition, Uncoded};
    use crate::data::synthetic_small;
    use crate::runtime::NativeEngine;

    fn pool_split() -> Split {
        synthetic_small(600, 10, 0.1, 91).train
    }

    fn make_pool(code: Box<dyn GradientCode>, per_part: usize, resp: ResponseModel) -> EcnPool {
        EcnPool::least_squares(
            0,
            pool_split(),
            code,
            per_part,
            resp,
            Xoshiro256pp::seed_from_u64(92),
        )
        .unwrap()
    }

    /// Reference: plain mini-batch gradient over the same rows the pool
    /// selects (recomputed from the deterministic generator).
    fn reference_grad(pool: &EcnPool, x: &Matrix, cycle: usize) -> Matrix {
        let data = pool_split();
        let k = pool.code.k();
        let (p, d) = x.shape();
        let mut acc = Matrix::zeros(p, d);
        let mut eng = NativeEngine::new();
        for pi in 0..k {
            let (blo, bhi) = pool.cursors[pi].batch_range(cycle);
            let lo = pool.partitions[pi].lo + blo;
            let hi = pool.partitions[pi].lo + bhi;
            let o = data.inputs.slice_rows(lo, hi);
            let t = data.targets.slice_rows(lo, hi);
            acc += &eng.grad_batch(&o, &t, x).unwrap();
        }
        acc.scaled(1.0 / k as f64)
    }

    /// A non-LS objective takes the native `grad_rows` path through the
    /// pool and still decodes to its exact mini-batch gradient.
    #[test]
    fn generic_objective_round_matches_direct_grad_rows() {
        use crate::problem::ObjectiveKind;
        let kind = ObjectiveKind::Huber { delta: 1.0 };
        let obj = kind.build(pool_split());
        let mut pool = EcnPool::new(
            0,
            Rc::clone(&obj),
            Box::new(CyclicRepetition::new(4, 1, 5).unwrap()),
            8,
            ResponseModel::default(),
            Xoshiro256pp::seed_from_u64(92),
        )
        .unwrap();
        let x = Matrix::full(3, 1, 0.4);
        let mut eng = NativeEngine::new();
        for cycle in 0..4 {
            let mut expect = Matrix::zeros(3, 1);
            let mut part = Matrix::zeros(3, 1);
            for pi in 0..4 {
                let (blo, bhi) = pool.cursors[pi].batch_range(cycle);
                let lo = pool.partitions[pi].lo + blo;
                let hi = pool.partitions[pi].lo + bhi;
                obj.grad_rows(&x, lo, hi, &mut part);
                expect.add_scaled(0.25, &part);
            }
            let res = pool.gradient_round(&x, cycle, &mut eng).unwrap();
            assert!(
                res.grad.max_abs_diff(&expect) < 1e-9,
                "cycle {cycle}: {}",
                res.grad.max_abs_diff(&expect)
            );
        }
    }

    #[test]
    fn uncoded_round_equals_minibatch_gradient() {
        let mut pool = make_pool(Box::new(Uncoded::new(3).unwrap()), 8, ResponseModel::default());
        let x = Matrix::full(3, 1, 0.5);
        let mut eng = NativeEngine::new();
        for cycle in 0..5 {
            let expect = reference_grad(&pool, &x, cycle);
            let res = pool.gradient_round(&x, cycle, &mut eng).unwrap();
            assert!(res.grad.max_abs_diff(&expect) < 1e-12);
            assert_eq!(res.responses_used, 3, "uncoded waits for all");
        }
    }

    #[test]
    fn coded_rounds_match_uncoded_gradient() {
        // Same batch geometry ⇒ cyclic and fractional must decode to the
        // exact same mini-batch gradient as computing everything.
        let x = Matrix::full(3, 1, -0.3);
        let mut eng = NativeEngine::new();
        for code in [
            Box::new(FractionalRepetition::new(4, 1).unwrap()) as Box<dyn GradientCode>,
            Box::new(CyclicRepetition::new(4, 1, 5).unwrap()) as Box<dyn GradientCode>,
        ] {
            let mut pool = make_pool(code, 8, ResponseModel::default());
            for cycle in 0..4 {
                let expect = reference_grad(&pool, &x, cycle);
                let res = pool.gradient_round(&x, cycle, &mut eng).unwrap();
                assert!(
                    res.grad.max_abs_diff(&expect) < 1e-9,
                    "cycle {cycle}: {}",
                    res.grad.max_abs_diff(&expect)
                );
                assert!(res.responses_used <= 4);
            }
        }
    }

    #[test]
    fn coded_avoids_straggler_delay_uncoded_pays_it() {
        let eps = 1.0; // huge straggler delay
        let resp = ResponseModel { straggler_count: 1, straggler_delay: eps, ..Default::default() };
        let x = Matrix::zeros(3, 1);
        let mut eng = NativeEngine::new();

        let mut uncoded = make_pool(Box::new(Uncoded::new(4).unwrap()), 8, resp.clone());
        let mut coded =
            make_pool(Box::new(CyclicRepetition::new(4, 1, 5).unwrap()), 8, resp.clone());

        let mut t_unc = 0.0;
        let mut t_cod = 0.0;
        for cycle in 0..20 {
            t_unc += uncoded.gradient_round(&x, cycle, &mut eng).unwrap().response_time;
            t_cod += coded.gradient_round(&x, cycle, &mut eng).unwrap().response_time;
        }
        // Uncoded waits out ε every round; coded should dodge nearly all.
        assert!(t_unc > 20.0 * eps * 0.9, "uncoded total {t_unc}");
        assert!(t_cod < t_unc / 10.0, "coded {t_cod} vs uncoded {t_unc}");
    }

    #[test]
    fn responses_used_is_r_for_coded() {
        let resp = ResponseModel { straggler_count: 1, ..Default::default() };
        let mut pool = make_pool(Box::new(FractionalRepetition::new(4, 1).unwrap()), 4, resp);
        let x = Matrix::zeros(3, 1);
        let mut eng = NativeEngine::new();
        let res = pool.gradient_round(&x, 0, &mut eng).unwrap();
        // FRC on (4,1) needs one member of each of 2 groups — the first
        // R=3 arrivals always contain both groups.
        assert!(res.responses_used <= 3);
    }

    /// The arrival slots warm up once and are reused every round: after
    /// many rounds the scratch vector holds at most K entries (one per
    /// possible responder), and every round's decode still matches the
    /// reference gradient (covered by the decode tests above).
    #[test]
    fn arrival_slots_are_reused_across_rounds() {
        let mut pool =
            make_pool(Box::new(CyclicRepetition::new(4, 1, 5).unwrap()), 8, Default::default());
        let x = Matrix::full(3, 1, 0.1);
        let mut eng = NativeEngine::new();
        for cycle in 0..30 {
            pool.gradient_round(&x, cycle, &mut eng).unwrap();
            assert!(pool.arrived.len() <= 4, "cycle {cycle}: {} slots", pool.arrived.len());
        }
        // All live slots kept the gradient shape (no per-round rebuild).
        assert!(pool.arrived.iter().all(|(_, m)| m.shape() == (3, 1)));
    }

    #[test]
    fn effective_batch_accounting() {
        let pool =
            make_pool(Box::new(CyclicRepetition::new(5, 2, 1).unwrap()), 6, Default::default());
        assert_eq!(pool.effective_batch(), 30);
    }

    use crate::latency::{FaultSpec, LatencySpec};

    fn latency_pool(code: Box<dyn GradientCode>, latency: &LatencySpec) -> EcnPool {
        EcnPool::with_latency(
            0,
            Rc::new(crate::problem::LeastSquares::new(pool_split())),
            code,
            8,
            ResponseModel::default(),
            latency,
            Xoshiro256pp::seed_from_u64(92),
        )
        .unwrap()
    }

    /// Fail-stop on an uncoded pool without a deadline stalls the round
    /// with a latency error; with a deadline it times out instead.
    #[test]
    fn fail_stop_uncoded_stalls_or_times_out() {
        let fault = FaultSpec { agent: None, ecn: 0, fail_at: 0.0, recover_at: None };
        let x = Matrix::zeros(3, 1);
        let mut eng = NativeEngine::new();

        let spec = LatencySpec { faults: vec![fault], ..Default::default() };
        let mut stalled = latency_pool(Box::new(Uncoded::new(4).unwrap()), &spec);
        match stalled.gradient_round_at(&x, 0, 1.0, &mut eng) {
            Err(crate::error::Error::Latency(msg)) => assert!(msg.contains("stalled"), "{msg}"),
            other => panic!("expected latency stall, got {other:?}"),
        }

        let spec = LatencySpec { deadline: Some(1e-3), ..spec };
        let mut timed = latency_pool(Box::new(Uncoded::new(4).unwrap()), &spec);
        match timed.gradient_round_at(&x, 0, 1.0, &mut eng).unwrap() {
            RoundOutcome::TimedOut { elapsed } => assert_eq!(elapsed, 1e-3),
            other => panic!("expected timeout, got {other:?}"),
        }
        // Before the fault fires (now < fail_at is impossible here with
        // fail_at = 0; use a later window instead).
        let spec = LatencySpec {
            faults: vec![FaultSpec { agent: None, ecn: 0, fail_at: 0.5, recover_at: Some(0.8) }],
            ..Default::default()
        };
        let mut windowed = latency_pool(Box::new(Uncoded::new(4).unwrap()), &spec);
        for (cycle, now) in [(0usize, 0.0), (1, 0.9)] {
            match windowed.gradient_round_at(&x, cycle, now, &mut eng).unwrap() {
                RoundOutcome::Decoded(r) => assert_eq!(r.responses_used, 4),
                other => panic!("expected decode at now={now}, got {other:?}"),
            }
        }
    }

    /// A coded pool rides through the same fail-stop fault: the dead
    /// node sorts last (t = ∞) and the first R arrivals decode.
    #[test]
    fn fail_stop_coded_decodes_from_survivors() {
        let spec = LatencySpec {
            faults: vec![FaultSpec { agent: None, ecn: 0, fail_at: 0.0, recover_at: None }],
            ..Default::default()
        };
        let mut pool = latency_pool(Box::new(CyclicRepetition::new(4, 1, 5).unwrap()), &spec);
        let x = Matrix::full(3, 1, 0.2);
        let mut eng = NativeEngine::new();
        for cycle in 0..4 {
            match pool.gradient_round_at(&x, cycle, 1.0, &mut eng).unwrap() {
                RoundOutcome::Decoded(r) => {
                    assert!(r.response_time.is_finite());
                    assert!(r.responses_used <= 3, "never waits for the dead node");
                }
                other => panic!("cycle {cycle}: expected decode, got {other:?}"),
            }
        }
    }

    /// Per-node clock stretch shifts response times but never the
    /// decoded gradient.
    #[test]
    fn clock_stretch_slows_but_preserves_gradient() {
        use crate::latency::ClockSpec;
        let x = Matrix::full(3, 1, 0.5);
        let mut eng = NativeEngine::new();
        let mut nominal =
            latency_pool(Box::new(Uncoded::new(4).unwrap()), &LatencySpec::default());
        let stretched_spec = LatencySpec {
            clocks: vec![ClockSpec { rate: 10.0, drift_ppm: 0.0, skew: 0.0 }],
            ..Default::default()
        };
        let mut stretched = latency_pool(Box::new(Uncoded::new(4).unwrap()), &stretched_spec);
        let a = nominal.gradient_round(&x, 0, &mut eng).unwrap();
        let b = stretched.gradient_round(&x, 0, &mut eng).unwrap();
        assert!(a.grad.max_abs_diff(&b.grad) < 1e-15, "gradient must not depend on clocks");
        let (ta, tb) = (a.response_time, b.response_time);
        assert!(tb > 5.0 * ta, "{tb} vs {ta}");
    }
}
