//! Service-time distributions — one [`LatencyModel`] per regime.

use crate::rng::{Rng, Xoshiro256pp};

/// Per-ECN service-time sampler: how long one ECN takes to compute and
/// return its coded partial gradient over `rows` examples.
///
/// Implementations must be deterministic functions of `(rows, rng)` so
/// that runs — and whole sweeps — replay bitwise from a seed; straggler
/// ε-injection ([`crate::ecn::ResponseModel::straggler_delay`]) and
/// per-node clock skew ([`super::ClockSpec`]) are applied by the caller
/// on top of the sampled value.
pub trait LatencyModel: std::fmt::Debug {
    /// Sample one response time (seconds) for `rows` processed rows.
    fn sample(&self, rows: usize, rng: &mut Xoshiro256pp) -> f64;

    /// Expected response time for `rows` rows (`f64::INFINITY` when the
    /// distribution has no finite mean) — distribution sanity tests and
    /// tables.
    fn mean(&self, rows: usize) -> f64;
}

/// The paper's baseline (§V-A): deterministic compute
/// `base + per_row·rows` plus exponential jitter with mean
/// `jitter_mean`. **Byte-identical** to the pre-latency-subsystem
/// `ResponseModel` draws — the default path of every run.
#[derive(Clone, Debug)]
pub struct UniformBaseline {
    pub base: f64,
    pub per_row: f64,
    pub jitter_mean: f64,
}

impl LatencyModel for UniformBaseline {
    fn sample(&self, rows: usize, rng: &mut Xoshiro256pp) -> f64 {
        let mut t = self.base + self.per_row * rows as f64;
        if self.jitter_mean > 0.0 {
            t += rng.exponential(1.0 / self.jitter_mean);
        }
        t
    }

    fn mean(&self, rows: usize) -> f64 {
        self.base + self.per_row * rows as f64 + self.jitter_mean
    }
}

/// Shifted-exponential service tail: every response pays a constant
/// `shift` (queueing / cold-start floor) plus `Exp(mean)`.
#[derive(Clone, Debug)]
pub struct ShiftedExponential {
    pub base: f64,
    pub per_row: f64,
    pub shift: f64,
    pub mean: f64,
}

impl LatencyModel for ShiftedExponential {
    fn sample(&self, rows: usize, rng: &mut Xoshiro256pp) -> f64 {
        let mut t = self.base + self.per_row * rows as f64 + self.shift;
        if self.mean > 0.0 {
            t += rng.exponential(1.0 / self.mean);
        }
        t
    }

    fn mean(&self, rows: usize) -> f64 {
        self.base + self.per_row * rows as f64 + self.shift + self.mean
    }
}

/// Heavy-tailed (Lomax / Pareto-II) jitter:
/// `scale · ((1−U)^(−1/alpha) − 1)`, support `[0, ∞)`, survival
/// `P[X > x] = (1 + x/scale)^(−alpha)`. For `alpha ≤ 1` the mean
/// diverges; for `alpha ≤ 2` the variance does — the regimes where the
/// slowest of K ECNs dominates every uncoded round.
#[derive(Clone, Debug)]
pub struct ParetoService {
    pub base: f64,
    pub per_row: f64,
    pub scale: f64,
    pub alpha: f64,
}

impl LatencyModel for ParetoService {
    fn sample(&self, rows: usize, rng: &mut Xoshiro256pp) -> f64 {
        // 1 − U ∈ (0, 1]: the tail draw is finite with probability 1.
        let u = 1.0 - rng.next_f64();
        let tail = self.scale * (u.powf(-1.0 / self.alpha) - 1.0);
        self.base + self.per_row * rows as f64 + tail
    }

    fn mean(&self, rows: usize) -> f64 {
        let det = self.base + self.per_row * rows as f64;
        if self.alpha > 1.0 {
            det + self.scale / (self.alpha - 1.0)
        } else {
            f64::INFINITY
        }
    }
}

/// Persistently slow device: the whole baseline response (compute and
/// jitter) is stretched by `factor`. [`super::LatencyKind::SlowNode`]
/// hands `factor > 1` to the designated slow ECNs and `factor = 1` to
/// the rest, so every node still draws exactly one jitter value per
/// round.
#[derive(Clone, Debug)]
pub struct SlowNodeService {
    pub base: f64,
    pub per_row: f64,
    pub jitter_mean: f64,
    pub factor: f64,
}

impl LatencyModel for SlowNodeService {
    fn sample(&self, rows: usize, rng: &mut Xoshiro256pp) -> f64 {
        let mut t = self.base + self.per_row * rows as f64;
        if self.jitter_mean > 0.0 {
            t += rng.exponential(1.0 / self.jitter_mean);
        }
        t * self.factor
    }

    fn mean(&self, rows: usize) -> f64 {
        (self.base + self.per_row * rows as f64 + self.jitter_mean) * self.factor
    }
}

/// Bimodal responses: baseline jitter, plus — with probability
/// `p_slow` per response — a `slow_delay` excursion (GC pause,
/// transient contention). Draws exactly two rng values per sample so
/// the stream layout is row-independent.
#[derive(Clone, Debug)]
pub struct BimodalService {
    pub base: f64,
    pub per_row: f64,
    pub jitter_mean: f64,
    pub p_slow: f64,
    pub slow_delay: f64,
}

impl LatencyModel for BimodalService {
    fn sample(&self, rows: usize, rng: &mut Xoshiro256pp) -> f64 {
        let mut t = self.base + self.per_row * rows as f64;
        if self.jitter_mean > 0.0 {
            t += rng.exponential(1.0 / self.jitter_mean);
        }
        if rng.next_f64() < self.p_slow {
            t += self.slow_delay;
        }
        t
    }

    fn mean(&self, rows: usize) -> f64 {
        self.base + self.per_row * rows as f64 + self.jitter_mean + self.p_slow * self.slow_delay
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean as stat_mean;

    fn sample_mean(model: &dyn LatencyModel, rows: usize, n: usize, seed: u64) -> f64 {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let xs: Vec<f64> = (0..n).map(|_| model.sample(rows, &mut rng)).collect();
        stat_mean(&xs)
    }

    #[test]
    fn baseline_matches_legacy_response_model_draws() {
        // The exact draw sequence of the pre-latency ResponseModel:
        // one exponential per sample when jitter_mean > 0.
        let m = UniformBaseline { base: 1e-5, per_row: 1e-6, jitter_mean: 2e-5 };
        let mut a = Xoshiro256pp::seed_from_u64(9);
        let mut b = Xoshiro256pp::seed_from_u64(9);
        for rows in [0usize, 8, 64] {
            let got = m.sample(rows, &mut a);
            let want = 1e-5 + 1e-6 * rows as f64 + b.exponential(1.0 / 2e-5);
            assert_eq!(got.to_bits(), want.to_bits(), "rows {rows}");
        }
        // Jitter off: deterministic, no rng perturbation of the value.
        let m0 = UniformBaseline { base: 2.0, per_row: 0.5, jitter_mean: 0.0 };
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        assert_eq!(m0.sample(4, &mut rng), 4.0);
    }

    #[test]
    fn sample_means_match_analytic_means() {
        let n = 40_000;
        let models: Vec<Box<dyn LatencyModel>> = vec![
            Box::new(UniformBaseline { base: 1e-4, per_row: 1e-6, jitter_mean: 3e-4 }),
            Box::new(ShiftedExponential { base: 1e-4, per_row: 1e-6, shift: 2e-4, mean: 3e-4 }),
            // alpha well above 2 so the sample mean concentrates.
            Box::new(ParetoService { base: 1e-4, per_row: 1e-6, scale: 3e-4, alpha: 3.5 }),
            Box::new(SlowNodeService { base: 1e-4, per_row: 1e-6, jitter_mean: 3e-4, factor: 7.0 }),
            Box::new(BimodalService {
                base: 1e-4,
                per_row: 1e-6,
                jitter_mean: 3e-4,
                p_slow: 0.2,
                slow_delay: 2e-3,
            }),
        ];
        for (i, m) in models.iter().enumerate() {
            let want = m.mean(16);
            let got = sample_mean(m.as_ref(), 16, n, 100 + i as u64);
            assert!(
                (got - want).abs() < 0.08 * want,
                "model {i}: sample mean {got} vs analytic {want}"
            );
        }
    }

    #[test]
    fn pareto_tail_is_heavier_than_exponential() {
        // Match the means (Lomax(alpha=1.5, scale) has mean 2·scale),
        // then compare far-tail exceedance rates.
        let scale = 1e-3;
        let pareto = ParetoService { base: 0.0, per_row: 0.0, scale, alpha: 1.5 };
        let expo = UniformBaseline { base: 0.0, per_row: 0.0, jitter_mean: 2.0 * scale };
        let threshold = 20.0 * scale; // 10× the common mean
        let n = 60_000;
        let count = |m: &dyn LatencyModel, seed: u64| {
            let mut rng = Xoshiro256pp::seed_from_u64(seed);
            (0..n).filter(|_| m.sample(0, &mut rng) > threshold).count()
        };
        let p_tail = count(&pareto, 7);
        let e_tail = count(&expo, 7);
        // Exp: P ≈ e^{-10} ≈ 4.5e-5; Lomax(1.5): P = 11^{-1.5} ≈ 2.7e-2.
        assert!(
            p_tail > 10 * (e_tail + 1),
            "pareto tail {p_tail} should dwarf exponential tail {e_tail}"
        );
    }

    #[test]
    fn pareto_mean_diverges_at_alpha_one() {
        let m = ParetoService { base: 0.0, per_row: 0.0, scale: 1e-3, alpha: 1.0 };
        assert!(m.mean(0).is_infinite());
        let m2 = ParetoService { base: 0.0, per_row: 0.0, scale: 1e-3, alpha: 2.0 };
        assert!((m2.mean(0) - 1e-3).abs() < 1e-15);
    }

    #[test]
    fn fixed_seed_streams_are_identical() {
        let models: Vec<Box<dyn LatencyModel>> = vec![
            Box::new(UniformBaseline { base: 1e-5, per_row: 1e-6, jitter_mean: 2e-5 }),
            Box::new(ShiftedExponential { base: 1e-5, per_row: 1e-6, shift: 5e-5, mean: 5e-5 }),
            Box::new(ParetoService { base: 1e-5, per_row: 1e-6, scale: 2e-5, alpha: 1.3 }),
            Box::new(SlowNodeService {
                base: 1e-5,
                per_row: 1e-6,
                jitter_mean: 2e-5,
                factor: 20.0,
            }),
            Box::new(BimodalService {
                base: 1e-5,
                per_row: 1e-6,
                jitter_mean: 2e-5,
                p_slow: 0.1,
                slow_delay: 1e-3,
            }),
        ];
        for m in &models {
            let mut a = Xoshiro256pp::seed_from_u64(42);
            let mut b = Xoshiro256pp::seed_from_u64(42);
            for rows in 0..50 {
                let x = m.sample(rows, &mut a);
                let y = m.sample(rows, &mut b);
                assert_eq!(x.to_bits(), y.to_bits());
                assert!(x >= 0.0 && x.is_finite());
            }
        }
    }
}
