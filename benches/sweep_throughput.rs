//! Bench: sweep-pool throughput — serial baseline vs the scoped worker
//! pool at 1/2/4 workers on a 24-job grid, plus a byte-identity check
//! of the summary JSON across worker counts.
//!
//! Expected shape: near-linear speedup up to the core count (jobs are
//! independent, compute-bound, allocation-light), with `--workers 1`
//! matching the serial loop.
//!
//! Emits `BENCH_pr7.json`:
//!
//! ```text
//! {
//!   "bench": "sweep_throughput",
//!   "jobs": 24, "iters_per_job": 2000, "profile": "full",
//!   "serial_s": …,
//!   "pool": [{"workers": 1, "wall_s": …, "speedup_vs_serial": …}, …],
//!   "json_identity_w1_w4": true
//! }
//! ```

use csadmm::coding::SchemeKind;
use csadmm::coordinator::{Algorithm, Driver, RunConfig};
use csadmm::data::synthetic_small;
use csadmm::ecn::ResponseModel;
use csadmm::runtime::{Engine, NativeEngine, NativeEngineFactory};
use csadmm::sweep::{run_sweep, SweepSpec, SweepSummary};
use csadmm::util::json::{write_json_file, Json};
use csadmm::util::table::Table;
use std::time::Instant;

fn grid(iters: usize) -> SweepSpec {
    SweepSpec::new(RunConfig {
        n_agents: 10,
        k_ecn: 2,
        s_tolerated: 1,
        minibatch: 16,
        rho: 0.2,
        max_iters: iters,
        eval_every: 100,
        response: ResponseModel { straggler_count: 1, ..Default::default() },
        ..Default::default()
    })
    .algos(vec![Algorithm::SIAdmm, Algorithm::CsIAdmm(SchemeKind::Cyclic)])
    .epsilons(vec![1e-3, 5e-3])
    .minibatches(vec![16, 32])
    .seeds(vec![1, 2, 3])
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let iters = if quick { 400 } else { 2_000 };
    let ds = synthetic_small(2_000, 200, 0.1, 42);
    let spec = grid(iters);
    let jobs = spec.num_jobs();

    // Serial baseline: the old hand-rolled loop — one engine, one job
    // at a time, same job order.
    let t0 = Instant::now();
    let mut engine = NativeEngine::new();
    let mut serial_traces = vec![];
    for job in spec.expand().expect("grid") {
        let trace = Driver::new(job.cfg.clone(), &ds)
            .expect("driver")
            .run(&mut engine as &mut dyn Engine)
            .expect("run");
        serial_traces.push(trace);
    }
    let t_serial = t0.elapsed();

    let mut table = Table::new(
        &format!("sweep_throughput — {jobs}-job grid, {iters} iters/job"),
        &["mode", "wall", "speedup vs serial"],
    );
    table.row(&["serial loop".into(), format!("{t_serial:.2?}"), "1.00x".into()]);

    let mut json_w1: Option<String> = None;
    let mut json_w4: Option<String> = None;
    let mut pool_entries = vec![];
    for workers in [1usize, 2, 4] {
        let t0 = Instant::now();
        let result =
            run_sweep(&spec, &ds, workers, &NativeEngineFactory).expect("sweep");
        let wall = t0.elapsed();
        // Pool results must match the serial loop trace-for-trace.
        for (a, b) in serial_traces.iter().zip(&result.jobs) {
            assert_eq!(a.points, b.trace.points, "pool diverged from serial");
        }
        let json = SweepSummary::from_result(&result).expect("summary").to_json().to_pretty();
        match workers {
            1 => json_w1 = Some(json),
            4 => json_w4 = Some(json),
            _ => {}
        }
        table.row(&[
            format!("pool --workers {workers}"),
            format!("{wall:.2?}"),
            format!("{:.2}x", t_serial.as_secs_f64() / wall.as_secs_f64()),
        ]);
        pool_entries.push(
            Json::obj()
                .num("workers", workers as f64)
                .num("wall_s", wall.as_secs_f64())
                .num("speedup_vs_serial", t_serial.as_secs_f64() / wall.as_secs_f64())
                .build(),
        );
    }
    assert_eq!(
        json_w1, json_w4,
        "summary JSON must be byte-identical across worker counts"
    );
    table.print();
    println!("JSON byte-identity across --workers 1/4: OK");

    let out = Json::obj()
        .str("bench", "sweep_throughput")
        .num("jobs", jobs as f64)
        .num("iters_per_job", iters as f64)
        .str("profile", if quick { "quick" } else { "full" })
        .num("serial_s", t_serial.as_secs_f64())
        .field("pool", Json::Arr(pool_entries))
        .field("json_identity_w1_w4", Json::Bool(true))
        .build();
    write_json_file(std::path::Path::new("BENCH_pr7.json"), &out)
        .expect("write BENCH_pr7.json");
    println!("wrote BENCH_pr7.json");
}
