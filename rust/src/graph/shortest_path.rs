//! BFS shortest paths and the shortest-path-cycle traversal (Fig. 1b).
//!
//! For networks without a (findable) Hamiltonian cycle, the paper [5]
//! forms the token route by concatenating shortest paths between
//! consecutive agents: the token still visits every agent once per cycle
//! but may pass *through* intermediate agents, each hop costing one
//! communication unit.

use super::Topology;
use crate::error::{Error, Result};
use std::collections::VecDeque;

/// BFS shortest path from `src` to `dst` (inclusive of both endpoints).
pub fn bfs_shortest_path(g: &Topology, src: usize, dst: usize) -> Option<Vec<usize>> {
    if src == dst {
        return Some(vec![src]);
    }
    let n = g.n();
    let mut prev = vec![usize::MAX; n];
    let mut queue = VecDeque::from([src]);
    prev[src] = src;
    while let Some(u) = queue.pop_front() {
        for &v in g.neighbors(u) {
            if prev[v] == usize::MAX {
                prev[v] = u;
                if v == dst {
                    let mut path = vec![dst];
                    let mut cur = dst;
                    while cur != src {
                        cur = prev[cur];
                        path.push(cur);
                    }
                    path.reverse();
                    return Some(path);
                }
                queue.push_back(v);
            }
        }
    }
    None
}

/// Build a closed token route that visits every agent at least once by
/// concatenating shortest paths `order[0] → order[1] → … → order[0]`
/// (paper §V-A, [35]). Returns the full hop sequence, where consecutive
/// entries are always adjacent in `g`; the sequence starts at
/// `order[0]` and ends just before returning to it.
///
/// The *update* order remains `order` (each agent's visit is the hop
/// where it appears as a path endpoint); intermediate relay hops only
/// cost communication.
pub fn shortest_path_cycle(g: &Topology, order: &[usize]) -> Result<Vec<usize>> {
    if order.is_empty() {
        return Err(Error::Graph("empty traversal order".into()));
    }
    if !g.is_connected() {
        return Err(Error::Graph("graph not connected".into()));
    }
    let mut route = vec![];
    let m = order.len();
    for i in 0..m {
        let src = order[i];
        let dst = order[(i + 1) % m];
        let path = bfs_shortest_path(g, src, dst)
            .ok_or_else(|| Error::Graph(format!("no path {src}->{dst}")))?;
        // Append path excluding its final node (start of next leg).
        route.extend_from_slice(&path[..path.len() - 1]);
    }
    Ok(route)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;
    use crate::rng::Rng;
    use crate::util::prop::property;

    #[test]
    fn path_on_line_graph() {
        let g = Topology::from_edges(4, &[(0, 1), (1, 2), (2, 3)]).unwrap();
        assert_eq!(bfs_shortest_path(&g, 0, 3).unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(bfs_shortest_path(&g, 2, 2).unwrap(), vec![2]);
    }

    #[test]
    fn path_is_shortest() {
        // Square with diagonal: 0-1-2-3-0 plus (0,2).
        let g = Topology::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)]).unwrap();
        assert_eq!(bfs_shortest_path(&g, 0, 2).unwrap().len(), 2);
    }

    #[test]
    fn no_path_disconnected() {
        let g = Topology::from_edges(4, &[(0, 1), (2, 3)]).unwrap();
        assert!(bfs_shortest_path(&g, 0, 3).is_none());
    }

    #[test]
    fn spc_on_spider_visits_everyone() {
        let g = Topology::spider(3, 2).unwrap();
        let order: Vec<usize> = (0..g.n()).collect();
        let route = shortest_path_cycle(&g, &order).unwrap();
        // Every agent appears.
        for v in 0..g.n() {
            assert!(route.contains(&v), "agent {v} missing from route");
        }
        // Consecutive hops adjacent (cyclically).
        for w in route.windows(2) {
            assert!(g.has_edge(w[0], w[1]), "hop {:?} not an edge", w);
        }
        assert!(g.has_edge(*route.last().unwrap(), route[0]));
        // Relay hops make the route longer than the agent count.
        assert!(route.len() > g.n());
    }

    #[test]
    fn spc_property_random_graphs() {
        property("spc valid on random graphs", 20, |rng| {
            let n = 5 + rng.below(12) as usize;
            let g = Topology::random_connected(n, 0.3, rng).unwrap();
            let mut order: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut order);
            let route = shortest_path_cycle(&g, &order).unwrap();
            for v in 0..n {
                assert!(route.contains(&v));
            }
            for w in route.windows(2) {
                assert!(g.has_edge(w[0], w[1]));
            }
            assert!(g.has_edge(*route.last().unwrap(), route[0]));
        });
    }
}
