//! Decentralized network topology and token-traversal patterns.
//!
//! The paper's network `G = (N, E)` has `E = η·N(N−1)/2` links for a
//! connectivity ratio η (§V-A). Tokens traverse agents either along a
//! Hamiltonian cycle (Fig. 1a) or, for non-Hamiltonian graphs, along a
//! cycle obtained by concatenating shortest paths (Fig. 1b, [5]).
//!
//! * [`Topology`] — undirected graph with adjacency queries, Metropolis
//!   mixing weights (for the DGD / EXTRA / D-ADMM baselines), and the
//!   random generator used by the experiments.
//! * `hamiltonian` — exact backtracking Hamiltonian-cycle search with
//!   degree-sorted branching (N ≤ 32 in all experiments).
//! * `shortest_path` — BFS shortest paths and the shortest-path-cycle
//!   construction.
//! * [`Traversal`] — the cycle abstraction the coordinator walks.

mod hamiltonian;
mod shortest_path;
mod topology;
mod traversal;

pub use hamiltonian::find_hamiltonian_cycle;
pub use shortest_path::{bfs_shortest_path, shortest_path_cycle};
pub use topology::Topology;
pub use traversal::{Traversal, TraversalKind};
