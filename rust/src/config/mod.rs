//! Experiment configuration: an INI/TOML-subset parser (no `serde`/
//! `toml` offline) plus typed conversion into
//! [`crate::coordinator::RunConfig`].
//!
//! Format: `key = value` lines, `[section]` headers, `#`/`;` comments.
//! Example (`examples/configs/usps.toml` ships with the repo):
//!
//! ```text
//! [run]
//! algo = csiadmm
//! scheme = cyclic
//! dataset = usps
//! n_agents = 10
//! k_ecn = 2
//! s = 1
//! minibatch = 16
//! rho = 0.1
//! max_iters = 4000
//! ```

mod parser;

pub use parser::{ConfigDoc, Value};

use crate::coding::SchemeKind;
use crate::comm::{CodecKind, CodecSpec};
use crate::coordinator::{Algorithm, RunConfig, TopologyKind};
use crate::data::DatasetName;
use crate::ecn::{BackendKind, ResponseModel, SocketSpec, TransportKind};
use crate::error::{Error, Result};
use crate::graph::TraversalKind;
use crate::latency::{ClockSpec, FaultSpec, LatencyKind, LatencySpec};
use crate::linalg::KernelTier;
use crate::problem::ObjectiveKind;
use crate::topology::{parse_join_event, MemberEvent, ScenarioKind, TopologySpec};

/// Apply the optional `[objective]` hyper-parameter section to a parsed
/// objective kind:
///
/// ```text
/// [objective]
/// lambda = 0.01   # logistic ridge weight
/// delta = 1.0     # huber transition point
/// l1 = 0.001      # elastic-net ℓ1 weight
/// l2 = 0.01       # elastic-net ridge weight
/// ```
///
/// Keys that don't apply to the kind are ignored, so one section can
/// parameterize a whole `objective = ls, logistic, huber, enet` sweep
/// axis.
pub fn apply_objective_params(kind: ObjectiveKind, doc: &ConfigDoc) -> ObjectiveKind {
    let sec = "objective";
    match kind {
        ObjectiveKind::Logistic { lambda } => ObjectiveKind::Logistic {
            lambda: doc.get_num(sec, "lambda").unwrap_or(lambda),
        },
        ObjectiveKind::Huber { delta } => ObjectiveKind::Huber {
            delta: doc.get_num(sec, "delta").unwrap_or(delta),
        },
        ObjectiveKind::ElasticNet { l1, l2 } => ObjectiveKind::ElasticNet {
            l1: doc.get_num(sec, "l1").unwrap_or(l1),
            l2: doc.get_num(sec, "l2").unwrap_or(l2),
        },
        ls => ls,
    }
}

/// Apply the optional `[latency]` parameter keys to a parsed latency
/// kind (the regime selected by `[latency] kind = …`, `--latency` or a
/// `[sweep] latency = …` axis):
///
/// ```text
/// [latency]
/// kind = pareto       # uniform|shifted-exp|pareto|slownode|bimodal
/// shift = 5e-5        # shifted-exp: constant floor (s)
/// mean = 5e-5         # shifted-exp: exponential tail mean (s)
/// scale = 2e-5        # pareto: tail scale (s)
/// alpha = 1.3         # pareto: tail index (smaller = heavier)
/// n_slow = 1          # slownode: slow ECNs per pool
/// factor = 20         # slownode: slowdown multiplier
/// p_slow = 0.1        # bimodal: probability a response straggles
/// slow_delay = 1e-3   # bimodal: extra delay of a slow response (s)
/// ```
///
/// Keys that don't apply to the kind are ignored, so one section can
/// parameterize a whole `latency = uniform, pareto, slownode` sweep
/// axis (mirroring [`apply_objective_params`]).
pub fn apply_latency_params(kind: LatencyKind, doc: &ConfigDoc) -> LatencyKind {
    let sec = "latency";
    match kind {
        LatencyKind::ShiftedExp { shift, mean } => LatencyKind::ShiftedExp {
            shift: doc.get_num(sec, "shift").unwrap_or(shift),
            mean: doc.get_num(sec, "mean").unwrap_or(mean),
        },
        LatencyKind::Pareto { scale, alpha } => LatencyKind::Pareto {
            scale: doc.get_num(sec, "scale").unwrap_or(scale),
            alpha: doc.get_num(sec, "alpha").unwrap_or(alpha),
        },
        LatencyKind::SlowNode { n_slow, factor } => LatencyKind::SlowNode {
            n_slow: doc.get_num(sec, "n_slow").map_or(n_slow, |v| v as usize),
            factor: doc.get_num(sec, "factor").unwrap_or(factor),
        },
        LatencyKind::Bimodal { p_slow, slow_delay } => LatencyKind::Bimodal {
            p_slow: doc.get_num(sec, "p_slow").unwrap_or(p_slow),
            slow_delay: doc.get_num(sec, "slow_delay").unwrap_or(slow_delay),
        },
        LatencyKind::Uniform => LatencyKind::Uniform,
    }
}

/// Apply the optional `[comm]` parameter keys to a parsed codec spec
/// (the codec selected by `[comm] codec = …`, `--compress` or a
/// `[sweep] compress = …` axis):
///
/// ```text
/// [comm]
/// codec = topk          # identity|f32|q<bits>|topk|randk, optional +ef
/// frac = 0.25           # topk/randk: kept fraction of entries (0,1]
/// error_feedback = true # wrap the codec in residual memory (same as +ef)
/// ```
///
/// Keys that don't apply to the kind are ignored, so one section can
/// parameterize a whole `compress = identity, q8, topk, randk` sweep
/// axis (mirroring [`apply_latency_params`]). Quantizer bits are *not*
/// a section key — they are always spelled in the token itself (`q8`),
/// so a `compress = q4, q8` axis can never be silently collapsed onto
/// one bit width. `error_feedback = true` composes with the `+ef`
/// token suffix (either enables it); anything other than a boolean
/// (`true`/`false`/`1`/`0`) is a config error, not a silent false —
/// a typo'd value must not quietly strand a biased sparsifier without
/// its residual memory.
pub fn apply_comm_params(spec: CodecSpec, doc: &ConfigDoc) -> Result<CodecSpec> {
    let sec = "comm";
    let kind = match spec.kind {
        CodecKind::TopK { frac } => {
            CodecKind::TopK { frac: doc.get_num(sec, "frac").unwrap_or(frac) }
        }
        CodecKind::RandK { frac } => {
            CodecKind::RandK { frac: doc.get_num(sec, "frac").unwrap_or(frac) }
        }
        exact => exact,
    };
    let ef_key = match doc.get_str(sec, "error_feedback") {
        None => false,
        Some(v) => match v.as_str() {
            "true" | "1" => true,
            "false" | "0" => false,
            other => {
                return Err(Error::Config(format!(
                    "comm.error_feedback: expected true/false, got '{other}'"
                )))
            }
        },
    };
    Ok(CodecSpec { kind, error_feedback: spec.error_feedback || ef_key })
}

/// Parse the full `[comm]` table into the run's [`CodecSpec`] (see
/// [`apply_comm_params`] for the keys). A missing table or a missing
/// `codec` key keeps the exact-token identity default — the golden
/// path.
pub fn comm_spec_from_doc(doc: &ConfigDoc) -> Result<CodecSpec> {
    let mut spec = CodecSpec::default();
    if let Some(tok) = doc.get_str("comm", "codec") {
        spec = CodecSpec::parse(&tok)
            .ok_or_else(|| Error::Config(format!("unknown comm codec '{tok}'")))?;
    }
    apply_comm_params(spec, doc)
}

/// Parse the full `[latency]` scenario: the regime kind (see
/// [`apply_latency_params`] for the per-kind keys), the decode
/// deadline, per-ECN clock heterogeneity and a fail-stop fault:
///
/// ```text
/// [latency]
/// kind = slownode
/// deadline = 5e-4       # per-round decode deadline (s)
/// rates = 1.0, 1.5      # per-ECN service-TIME multipliers (2.0 = half
///                       # speed), cycled over the K ECNs
/// drift_ppm = 0, 200    # per-ECN clock drift (ppm), cycled
/// skews = 0, 1e-5       # per-ECN constant skew (s), cycled
/// fail_ecn = 0          # fail-stop: ECN index that dies
/// fail_at = 0.01        # … at this simulated time (s)
/// recover_at = 0.05     # … optionally recovering here (s)
/// fail_agent = 2        # … at this agent only (default: every agent)
/// ```
pub fn latency_spec_from_doc(doc: &ConfigDoc) -> Result<LatencySpec> {
    let sec = "latency";
    let mut spec = LatencySpec::default();
    if let Some(tok) = doc.get_str(sec, "kind") {
        let kind = LatencyKind::parse(&tok)
            .ok_or_else(|| Error::Config(format!("unknown latency kind '{tok}'")))?;
        spec.kind = apply_latency_params(kind, doc);
    }
    if let Some(d) = doc.get_num(sec, "deadline") {
        spec.deadline = Some(d);
    }
    let rates = parse_f64_list(doc, sec, "rates")?;
    let drifts = parse_f64_list(doc, sec, "drift_ppm")?;
    let skews = parse_f64_list(doc, sec, "skews")?;
    let n_clocks = rates.len().max(drifts.len()).max(skews.len());
    if n_clocks > 0 {
        let pick = |xs: &[f64], i: usize, default: f64| {
            if xs.is_empty() {
                default
            } else {
                xs[i % xs.len()]
            }
        };
        spec.clocks = (0..n_clocks)
            .map(|i| ClockSpec {
                rate: pick(&rates, i, 1.0),
                drift_ppm: pick(&drifts, i, 0.0),
                skew: pick(&skews, i, 0.0),
            })
            .collect();
    }
    if let Some(ecn) = doc.get_num(sec, "fail_ecn") {
        spec.faults.push(FaultSpec {
            agent: doc.get_num(sec, "fail_agent").map(|v| v as usize),
            ecn: ecn as usize,
            fail_at: doc.get_num(sec, "fail_at").unwrap_or(0.0),
            recover_at: doc.get_num(sec, "recover_at"),
        });
    }
    Ok(spec)
}

/// Apply the optional `[topology]` numeric parameter keys to a parsed
/// scenario kind's spec (the scenario selected by
/// `[topology] scenario = …`, `--topology` or a `[sweep] topo = …`
/// axis):
///
/// ```text
/// [topology]
/// scenario = partition   # static|churn|partition|flaky-links
/// churn_period = 200     # churn: iterations between leave waves
/// churn_span = 80        # churn: how long each agent stays away
/// churn_agents = 2       # churn: how many (seed-chosen) agents churn
/// partition_at = 300     # partition: iteration the cut lands
/// partition_repair = 600 # partition: iteration the cut heals
/// partition_frac = 0.3   # partition: minority-side agent fraction
/// link_period = 150      # flaky-links: iterations between failures
/// link_span = 50         # flaky-links: how long each link is down
/// link_count = 2         # flaky-links: how many links flap
/// ```
///
/// Keys that don't apply to the scenario are ignored, so one section
/// can parameterize a whole `topo = static, churn, partition` sweep
/// axis (mirroring [`apply_latency_params`]).
pub fn apply_topology_params(mut spec: TopologySpec, doc: &ConfigDoc) -> TopologySpec {
    let sec = "topology";
    macro_rules! set_usize {
        ($field:ident, $key:literal) => {
            if let Some(v) = doc.get_num(sec, $key) {
                spec.$field = v as usize;
            }
        };
    }
    set_usize!(churn_period, "churn_period");
    set_usize!(churn_span, "churn_span");
    set_usize!(churn_agents, "churn_agents");
    set_usize!(partition_at, "partition_at");
    set_usize!(partition_repair, "partition_repair");
    set_usize!(link_period, "link_period");
    set_usize!(link_span, "link_span");
    set_usize!(link_count, "link_count");
    if let Some(v) = doc.get_num(sec, "partition_frac") {
        spec.partition_frac = v;
    }
    spec
}

/// Parse the full `[topology]` dynamics table: the scenario preset (see
/// [`apply_topology_params`] for the per-scenario keys) plus explicit
/// membership events:
///
/// ```text
/// [topology]
/// scenario = static      # plus explicit events on top:
/// leave = 3@200:400, 5@600   # agent@from[:until] — away windows
/// join = 7@250               # agent@iter — not a member before iter
/// ```
///
/// A missing table (or `scenario = static` with no events) keeps the
/// static default — the golden path, byte-identical to the
/// pre-subsystem runs.
pub fn topology_spec_from_doc(doc: &ConfigDoc) -> Result<TopologySpec> {
    let sec = "topology";
    let mut spec = TopologySpec::default();
    if let Some(tok) = doc.get_str(sec, "scenario") {
        spec.scenario = ScenarioKind::parse(&tok)
            .ok_or_else(|| Error::Config(format!("unknown topology scenario '{tok}'")))?;
    }
    spec = apply_topology_params(spec, doc);
    if let Some(tokens) = doc.get_list(sec, "leave") {
        spec.leaves =
            tokens.iter().map(|t| MemberEvent::parse(t)).collect::<Result<Vec<_>>>()?;
    }
    if let Some(tokens) = doc.get_list(sec, "join") {
        spec.joins =
            tokens.iter().map(|t| parse_join_event(t)).collect::<Result<Vec<_>>>()?;
    }
    spec.validate()?;
    Ok(spec)
}

/// Parse the `[socket]` deployment table for `backend = socket`:
///
/// ```text
/// [socket]
/// transport = unix        # unix|tcp (default: unix where available)
/// dir = /tmp/csadmm       # unix: socket-file directory (default: temp dir)
/// host = 127.0.0.1        # tcp: bind host
/// port = 0                # tcp: 0 = ephemeral, else base + agent id
/// accept_timeout_secs = 10   # worker connect + handshake budget
/// recv_deadline_secs = 30    # per-wait half-open-peer guard
/// time_scale = 0          # real seconds slept per modeled second
/// worker_exe = target/release/csadmm   # default: the current binary
/// ```
///
/// The mere *presence* of the table (even empty) marks the spec
/// `configured` — `backend = socket` without it is rejected by
/// [`RunConfig::validate`], so a config can't silently spawn worker
/// processes.
pub fn socket_spec_from_doc(doc: &ConfigDoc) -> Result<SocketSpec> {
    let sec = "socket";
    let mut spec = SocketSpec {
        configured: doc.section_names().iter().any(|s| *s == sec),
        ..SocketSpec::default()
    };
    if let Some(tok) = doc.get_str(sec, "transport") {
        spec.transport = TransportKind::parse(&tok)
            .ok_or_else(|| Error::Config(format!("unknown socket transport '{tok}'")))?;
    }
    if let Some(dir) = doc.get_str(sec, "dir") {
        spec.dir = Some(dir.into());
    }
    if let Some(host) = doc.get_str(sec, "host") {
        spec.host = host;
    }
    if let Some(port) = doc.get_num(sec, "port") {
        if port < 0.0 || port > u16::MAX as f64 || port.fract() != 0.0 {
            return Err(Error::Config(format!(
                "socket.port must be an integer in 0..={}, got {port}",
                u16::MAX
            )));
        }
        spec.port = port as u16;
    }
    for (key, slot) in [
        ("accept_timeout_secs", &mut spec.accept_timeout),
        ("recv_deadline_secs", &mut spec.recv_deadline),
    ] {
        if let Some(v) = doc.get_num(sec, key) {
            if !v.is_finite() || v <= 0.0 {
                return Err(Error::Config(format!(
                    "socket.{key} must be a positive number of seconds, got {v}"
                )));
            }
            *slot = std::time::Duration::from_secs_f64(v);
        }
    }
    if let Some(v) = doc.get_num(sec, "time_scale") {
        if !v.is_finite() || v < 0.0 {
            return Err(Error::Config(format!(
                "socket.time_scale must be finite and >= 0, got {v}"
            )));
        }
        spec.time_scale = v;
    }
    if let Some(exe) = doc.get_str(sec, "worker_exe") {
        spec.worker_exe = Some(exe.into());
    }
    Ok(spec)
}

/// Parse an optional comma-separated f64 list from a config key.
fn parse_f64_list(doc: &ConfigDoc, sec: &str, key: &str) -> Result<Vec<f64>> {
    match doc.get_list(sec, key) {
        None => Ok(vec![]),
        Some(tokens) => tokens
            .iter()
            .map(|t| {
                t.parse::<f64>()
                    .map_err(|_| Error::Config(format!("{sec}.{key}: bad entry '{t}'")))
            })
            .collect(),
    }
}

/// Parse a run config (and dataset choice) from a config document's
/// `[run]` section, starting from defaults.
pub fn run_config_from_doc(doc: &ConfigDoc) -> Result<(RunConfig, DatasetName)> {
    let mut cfg = RunConfig::default();
    let sec = "run";
    let mut dataset = DatasetName::Synthetic;

    if let Some(v) = doc.get_str(sec, "objective") {
        cfg.objective = ObjectiveKind::parse(&v)
            .ok_or_else(|| Error::Config(format!("unknown objective '{v}'")))?;
    }
    cfg.objective = apply_objective_params(cfg.objective, doc);
    if let Some(v) = doc.get_str(sec, "algo") {
        cfg.algo = match v.as_str() {
            "iadmm" => Algorithm::IAdmmExact,
            "siadmm" => Algorithm::SIAdmm,
            "wadmm" => Algorithm::WAdmm,
            "csiadmm" => {
                let scheme = doc
                    .get_str(sec, "scheme")
                    .and_then(|s| SchemeKind::parse(&s))
                    .unwrap_or(SchemeKind::Cyclic);
                Algorithm::CsIAdmm(scheme)
            }
            other => return Err(Error::Config(format!("unknown algo '{other}'"))),
        };
    }
    if let Some(v) = doc.get_str(sec, "dataset") {
        dataset = DatasetName::parse(&v)
            .ok_or_else(|| Error::Config(format!("unknown dataset '{v}'")))?;
    }
    if let Some(v) = doc.get_str(sec, "backend") {
        cfg.backend = BackendKind::parse(&v).ok_or_else(|| {
            Error::Config(format!("unknown backend '{v}' (expected sim, threaded or socket)"))
        })?;
    }
    if let Some(v) = doc.get_str(sec, "kernel") {
        cfg.kernel = KernelTier::parse(&v).ok_or_else(|| {
            Error::Config(format!("unknown kernel '{v}' (expected exact or fast)"))
        })?;
    }
    if let Some(v) = doc.get_str(sec, "traversal") {
        cfg.traversal = match v.as_str() {
            "hamiltonian" => TraversalKind::Hamiltonian,
            "spc" | "shortest-path" => TraversalKind::ShortestPathCycle,
            "random-walk" => TraversalKind::RandomWalk,
            other => return Err(Error::Config(format!("unknown traversal '{other}'"))),
        };
    }
    if let Some(v) = doc.get_str(sec, "topology") {
        cfg.topology = match v.as_str() {
            "random" => TopologyKind::Random,
            "spider" => TopologyKind::Spider,
            other => return Err(Error::Config(format!("unknown topology '{other}'"))),
        };
    }
    macro_rules! set_num {
        ($field:ident, $key:literal, $ty:ty) => {
            if let Some(v) = doc.get_num(sec, $key) {
                cfg.$field = v as $ty;
            }
        };
    }
    set_num!(n_agents, "n_agents", usize);
    set_num!(k_ecn, "k_ecn", usize);
    set_num!(s_tolerated, "s", usize);
    set_num!(minibatch, "minibatch", usize);
    set_num!(rho, "rho", f64);
    set_num!(eta, "eta", f64);
    set_num!(max_iters, "max_iters", usize);
    set_num!(eval_every, "eval_every", usize);
    set_num!(seed, "seed", u64);
    set_num!(shard_threads, "shard_threads", usize);
    if let Some(v) = doc.get_num(sec, "c_tau") {
        cfg.c_tau = Some(v);
    }
    if let Some(v) = doc.get_num(sec, "c_gamma") {
        cfg.c_gamma = Some(v);
    }
    // Straggler / response model.
    let mut resp = ResponseModel::default();
    if let Some(v) = doc.get_num("stragglers", "count") {
        resp.straggler_count = v as usize;
    }
    if let Some(v) = doc.get_num("stragglers", "delay") {
        resp.straggler_delay = v;
    }
    if let Some(v) = doc.get_num("stragglers", "per_row") {
        resp.per_row = v;
    }
    cfg.response = resp;
    // Latency scenario ([latency] table).
    cfg.latency = latency_spec_from_doc(doc)?;
    // Membership dynamics ([topology] table; distinct from the [run]
    // `topology` key above, which picks the graph *shape*).
    cfg.dynamics = topology_spec_from_doc(doc)?;
    // Socket-backend deployment knobs ([socket] table); its presence is
    // the opt-in gate for backend = socket.
    cfg.socket = socket_spec_from_doc(doc)?;
    // Token codec ([comm] table); the legacy [run] quantize_bits key
    // keeps working as the q<bits> alias.
    cfg.comm = comm_spec_from_doc(doc)?;
    if let Some(v) = doc.get_num(sec, "quantize_bits") {
        cfg.quantize_bits = Some(v as u32);
    }
    cfg.codec_spec()?.validate()?;
    // Degenerate shapes (zero agents/ECNs/batch/iterations, a partition
    // scenario without enough agents to cut) are config errors at load
    // time, not panics at the first modulo deeper in the run.
    cfg.validate()?;
    Ok((cfg, dataset))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_round_trip() {
        let text = r#"
# experiment
[run]
algo = csiadmm
scheme = fractional
dataset = usps
n_agents = 8
k_ecn = 4
s = 1
minibatch = 16
rho = 0.25
max_iters = 500
traversal = spc

[stragglers]
count = 1
delay = 0.01
"#;
        let doc = ConfigDoc::parse(text).unwrap();
        let (cfg, ds) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.algo, Algorithm::CsIAdmm(SchemeKind::Fractional));
        assert_eq!(ds, DatasetName::UspsLike);
        assert_eq!(cfg.n_agents, 8);
        assert_eq!(cfg.k_ecn, 4);
        assert_eq!(cfg.s_tolerated, 1);
        assert!((cfg.rho - 0.25).abs() < 1e-12);
        assert_eq!(cfg.traversal, TraversalKind::ShortestPathCycle);
        assert_eq!(cfg.response.straggler_count, 1);
        assert!((cfg.response.straggler_delay - 0.01).abs() < 1e-15);
    }

    #[test]
    fn unknown_algo_rejected() {
        let doc = ConfigDoc::parse("[run]\nalgo = nope\n").unwrap();
        assert!(run_config_from_doc(&doc).is_err());
    }

    #[test]
    fn objective_parsing_with_param_overrides() {
        let doc = ConfigDoc::parse(
            "[run]\nobjective = enet\n\n[objective]\nl1 = 0.05\nl2 = 0.2\n",
        )
        .unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.objective, ObjectiveKind::ElasticNet { l1: 0.05, l2: 0.2 });
        // Defaults survive when the section is absent.
        let doc = ConfigDoc::parse("[run]\nobjective = huber\n").unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.objective, ObjectiveKind::Huber { delta: 1.0 });
        // Unknown names error; missing key keeps least squares.
        assert!(run_config_from_doc(&ConfigDoc::parse("[run]\nobjective = nope\n").unwrap())
            .is_err());
        let (cfg, _) = run_config_from_doc(&ConfigDoc::parse("").unwrap()).unwrap();
        assert_eq!(cfg.objective, ObjectiveKind::LeastSquares);
    }

    #[test]
    fn defaults_without_sections() {
        let doc = ConfigDoc::parse("").unwrap();
        let (cfg, ds) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.n_agents, RunConfig::default().n_agents);
        assert_eq!(ds, DatasetName::Synthetic);
        assert_eq!(cfg.latency, LatencySpec::default());
        assert_eq!(cfg.backend, BackendKind::Sim);
    }

    /// Degenerate `[run]` values that once panicked deeper in the run
    /// (modulo by zero at the eval gate, `eff % k_ecn`, the spider
    /// `n - 1`, the partition cut's `1..n-1` clamp) must surface as
    /// config errors at load time.
    #[test]
    fn degenerate_run_keys_are_config_errors() {
        for toml in [
            "[run]\neval_every = 0\n",
            "[run]\nk_ecn = 0\n",
            "[run]\nn_agents = 0\n",
            "[run]\nminibatch = 0\n",
            "[run]\nmax_iters = 0\n",
            "[run]\nshard_threads = 0\n",
            "[run]\nn_agents = 1\n\n[topology]\nscenario = partition\n",
        ] {
            let doc = ConfigDoc::parse(toml).unwrap();
            assert!(
                run_config_from_doc(&doc).is_err(),
                "{toml:?} must be rejected as a config error"
            );
        }
    }

    #[test]
    fn shard_threads_key_round_trip() {
        let doc = ConfigDoc::parse("[run]\nshard_threads = 4\n").unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.shard_threads, 4);
        let default = ConfigDoc::parse("[run]\n").unwrap();
        let (cfg, _) = run_config_from_doc(&default).unwrap();
        assert_eq!(cfg.shard_threads, 1, "sequential legacy default");
    }

    #[test]
    fn kernel_key_round_trip() {
        let doc = ConfigDoc::parse("[run]\nkernel = fast\n").unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.kernel, KernelTier::Fast);
        let doc = ConfigDoc::parse("[run]\nkernel = exact\n").unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.kernel, KernelTier::Exact);
        let default = ConfigDoc::parse("[run]\n").unwrap();
        let (cfg, _) = run_config_from_doc(&default).unwrap();
        assert_eq!(cfg.kernel, KernelTier::Exact, "exact tier is the golden default");
        let bad = ConfigDoc::parse("[run]\nkernel = warp\n").unwrap();
        assert!(run_config_from_doc(&bad).is_err());
    }

    #[test]
    fn backend_key_round_trip() {
        let doc = ConfigDoc::parse("[run]\nbackend = threaded\n").unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.backend, BackendKind::Threaded);
        let doc = ConfigDoc::parse("[run]\nbackend = sim\n").unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.backend, BackendKind::Sim);
        let bad = ConfigDoc::parse("[run]\nbackend = quantum\n").unwrap();
        assert!(run_config_from_doc(&bad).is_err());
    }

    #[test]
    fn socket_table_round_trip() {
        let text = r#"
[run]
backend = socket

[socket]
transport = tcp
host = 10.0.0.7
port = 9000
accept_timeout_secs = 2.5
recv_deadline_secs = 1
time_scale = 0
worker_exe = /opt/csadmm/bin/csadmm
"#;
        let doc = ConfigDoc::parse(text).unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.backend, BackendKind::Socket);
        assert!(cfg.socket.configured);
        assert_eq!(cfg.socket.transport, TransportKind::Tcp);
        assert_eq!(cfg.socket.host, "10.0.0.7");
        assert_eq!(cfg.socket.port, 9000);
        assert_eq!(cfg.socket.accept_timeout, std::time::Duration::from_millis(2_500));
        assert_eq!(cfg.socket.recv_deadline, std::time::Duration::from_secs(1));
        assert_eq!(cfg.socket.time_scale, 0.0);
        assert_eq!(
            cfg.socket.worker_exe.as_deref(),
            Some(std::path::Path::new("/opt/csadmm/bin/csadmm"))
        );
        // An empty table still counts as configured (the opt-in gate)…
        let doc = ConfigDoc::parse("[run]\nbackend = socket\n\n[socket]\n").unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert!(cfg.socket.configured);
        // …and backend = socket without the table is a config error.
        let doc = ConfigDoc::parse("[run]\nbackend = socket\n").unwrap();
        match run_config_from_doc(&doc).err() {
            Some(Error::Config(msg)) => assert!(msg.contains("[socket]"), "{msg}"),
            other => panic!("expected Error::Config, got {other:?}"),
        }
        // A [socket] table without backend = socket is inert.
        let doc = ConfigDoc::parse("[socket]\ntime_scale = 0\n").unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.backend, BackendKind::Sim);
        // Degenerate knobs are config errors, not runtime surprises.
        for bad in [
            "[socket]\ntransport = carrier-pigeon\n",
            "[socket]\nport = 70000\n",
            "[socket]\nport = -1\n",
            "[socket]\nport = 80.5\n",
            "[socket]\naccept_timeout_secs = 0\n",
            "[socket]\nrecv_deadline_secs = -2\n",
            "[socket]\ntime_scale = -1\n",
        ] {
            assert!(
                run_config_from_doc(&ConfigDoc::parse(bad).unwrap()).is_err(),
                "{bad:?} must be rejected"
            );
        }
    }

    #[test]
    fn comm_table_round_trip() {
        let doc = ConfigDoc::parse(
            "[run]\nn_agents = 6\n\n[comm]\ncodec = topk\nfrac = 0.1\nerror_feedback = true\n",
        )
        .unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.comm.kind, CodecKind::TopK { frac: 0.1 });
        assert!(cfg.comm.error_feedback);
        // Quantizer bits live in the token itself — never overridden by
        // a section key (a q4/q8 axis must stay two distinct codecs).
        let doc = ConfigDoc::parse("[comm]\ncodec = q4\nfrac = 0.5\n").unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.comm.kind, CodecKind::Quantize { bits: 4 });
        assert!(!cfg.comm.error_feedback);
        let doc = ConfigDoc::parse("[comm]\ncodec = randk+ef\n").unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert!(cfg.comm.error_feedback);
        // Missing table keeps the exact-token golden default.
        let (cfg, _) = run_config_from_doc(&ConfigDoc::parse("").unwrap()).unwrap();
        assert!(cfg.comm.is_plain_identity());
        // Unknown codecs and out-of-range params are config errors.
        assert!(run_config_from_doc(&ConfigDoc::parse("[comm]\ncodec = warp\n").unwrap())
            .is_err());
        assert!(run_config_from_doc(&ConfigDoc::parse("[comm]\ncodec = q99\n").unwrap())
            .is_err());
        assert!(run_config_from_doc(
            &ConfigDoc::parse("[comm]\ncodec = topk\nfrac = 0\n").unwrap()
        )
        .is_err());
        // error_feedback is a strict boolean: a typo'd value must fail
        // loudly, not silently strand a biased codec without EF.
        for bad in ["yes", "tru", "2"] {
            let doc =
                ConfigDoc::parse(&format!("[comm]\ncodec = topk\nerror_feedback = {bad}\n"))
                    .unwrap();
            assert!(run_config_from_doc(&doc).is_err(), "'{bad}' must be rejected");
        }
        let doc = ConfigDoc::parse("[comm]\ncodec = topk\nerror_feedback = 0\n").unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert!(!cfg.comm.error_feedback);
    }

    #[test]
    fn legacy_quantize_bits_key_still_parses() {
        let doc = ConfigDoc::parse("[run]\nquantize_bits = 8\n").unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.quantize_bits, Some(8));
        assert_eq!(cfg.codec_spec().unwrap().kind, CodecKind::Quantize { bits: 8 });
        // Conflicting with a non-identity [comm] codec is rejected.
        let doc =
            ConfigDoc::parse("[run]\nquantize_bits = 8\n\n[comm]\ncodec = f32\n").unwrap();
        assert!(run_config_from_doc(&doc).is_err());
    }

    #[test]
    fn latency_table_full_round_trip() {
        let text = r#"
[run]
n_agents = 6

[latency]
kind = pareto
scale = 1e-4
alpha = 1.8
deadline = 5e-4
rates = 1.0, 2.0
drift_ppm = 0, 300
skews = 0, 1e-5
fail_ecn = 1
fail_at = 0.01
recover_at = 0.05
"#;
        let doc = ConfigDoc::parse(text).unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.latency.kind, LatencyKind::Pareto { scale: 1e-4, alpha: 1.8 });
        assert_eq!(cfg.latency.deadline, Some(5e-4));
        assert_eq!(cfg.latency.clocks.len(), 2);
        assert_eq!(cfg.latency.clocks[1].rate, 2.0);
        assert_eq!(cfg.latency.clocks[1].drift_ppm, 300.0);
        assert_eq!(cfg.latency.clocks[1].skew, 1e-5);
        assert_eq!(
            cfg.latency.faults,
            vec![FaultSpec { agent: None, ecn: 1, fail_at: 0.01, recover_at: Some(0.05) }]
        );
    }

    #[test]
    fn topology_table_round_trip() {
        let text = r#"
[run]
n_agents = 8

[topology]
scenario = partition
partition_at = 400
partition_repair = 900
partition_frac = 0.25
leave = 3@200:400, 5@600
join = 7@250
"#;
        let doc = ConfigDoc::parse(text).unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.dynamics.scenario, ScenarioKind::Partition);
        assert_eq!(cfg.dynamics.partition_at, 400);
        assert_eq!(cfg.dynamics.partition_repair, 900);
        assert!((cfg.dynamics.partition_frac - 0.25).abs() < 1e-12);
        assert_eq!(cfg.dynamics.leaves.len(), 2);
        assert_eq!(cfg.dynamics.leaves[1], MemberEvent::parse("5@600").unwrap());
        assert_eq!(cfg.dynamics.joins, vec![(7, 250)]);
        // Missing table keeps the static golden default.
        let (cfg, _) = run_config_from_doc(&ConfigDoc::parse("").unwrap()).unwrap();
        assert!(cfg.dynamics.is_static());
        // Unknown scenarios, malformed events and degenerate presets
        // are config errors.
        assert!(run_config_from_doc(
            &ConfigDoc::parse("[topology]\nscenario = mesh\n").unwrap()
        )
        .is_err());
        assert!(run_config_from_doc(
            &ConfigDoc::parse("[topology]\nleave = 3@400:200\n").unwrap()
        )
        .is_err());
        assert!(run_config_from_doc(
            &ConfigDoc::parse(
                "[topology]\nscenario = partition\npartition_at = 500\npartition_repair = 100\n"
            )
            .unwrap()
        )
        .is_err());
        // The [run] topology key (graph shape) stays independent of the
        // [topology] table (membership dynamics).
        let doc = ConfigDoc::parse(
            "[run]\ntopology = spider\n\n[topology]\nscenario = churn\nchurn_agents = 1\n",
        )
        .unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.topology, TopologyKind::Spider);
        assert_eq!(cfg.dynamics.scenario, ScenarioKind::Churn);
        assert_eq!(cfg.dynamics.churn_agents, 1);
    }

    #[test]
    fn latency_kind_param_overrides_per_kind() {
        let doc = ConfigDoc::parse(
            "[latency]\nkind = slownode\nn_slow = 2\nfactor = 50\nscale = 99\n",
        )
        .unwrap();
        let spec = latency_spec_from_doc(&doc).unwrap();
        assert_eq!(spec.kind, LatencyKind::SlowNode { n_slow: 2, factor: 50.0 });
        // Defaults survive when keys are absent; shared section
        // parameterizes other kinds too.
        let shifted = apply_latency_params(LatencyKind::parse("shifted-exp").unwrap(), &doc);
        assert_eq!(shifted, LatencyKind::ShiftedExp { shift: 5e-5, mean: 5e-5 });
        let pareto = apply_latency_params(LatencyKind::parse("pareto").unwrap(), &doc);
        assert_eq!(pareto, LatencyKind::Pareto { scale: 99.0, alpha: 1.3 });
        // Unknown kinds error.
        let bad = ConfigDoc::parse("[latency]\nkind = warp\n").unwrap();
        assert!(latency_spec_from_doc(&bad).is_err());
        // Bad clock entries error.
        let bad2 = ConfigDoc::parse("[latency]\nrates = 1.0, fast\n").unwrap();
        assert!(latency_spec_from_doc(&bad2).is_err());
    }
}
