//! Per-run trace recording and JSON export.

use crate::util::json::Json;

/// One evaluation point along a run.
#[derive(Clone, Debug, PartialEq)]
pub struct TracePoint {
    /// Iteration k.
    pub iter: usize,
    /// Cumulative communication units.
    pub comm_units: f64,
    /// Cumulative simulated running time (s).
    pub sim_time: f64,
    /// Relative-error accuracy (Eq. 23).
    pub accuracy: f64,
    /// Test MSE at the consensus variable.
    pub test_mse: f64,
}

/// A labelled series of trace points (one run of one algorithm).
#[derive(Clone, Debug, Default)]
pub struct Trace {
    /// Algorithm / configuration label ("sI-ADMM M=32", …).
    pub label: String,
    pub points: Vec<TracePoint>,
}

impl Trace {
    /// New empty trace.
    pub fn new(label: &str) -> Self {
        Self { label: label.to_string(), points: vec![] }
    }

    /// Append a point.
    pub fn push(&mut self, p: TracePoint) {
        self.points.push(p);
    }

    /// Final accuracy (NaN if empty).
    pub fn final_accuracy(&self) -> f64 {
        self.points.last().map(|p| p.accuracy).unwrap_or(f64::NAN)
    }

    /// Final test MSE (NaN if empty).
    pub fn final_test_mse(&self) -> f64 {
        self.points.last().map(|p| p.test_mse).unwrap_or(f64::NAN)
    }

    /// Final simulated running time (NaN if empty) — sweep summaries.
    pub fn final_sim_time(&self) -> f64 {
        self.points.last().map(|p| p.sim_time).unwrap_or(f64::NAN)
    }

    /// Final cumulative communication units (NaN if empty) — sweep
    /// summaries.
    pub fn final_comm_units(&self) -> f64 {
        self.points.last().map(|p| p.comm_units).unwrap_or(f64::NAN)
    }

    /// First iteration at which accuracy drops below `threshold`
    /// (convergence-speed comparisons, Fig. 5).
    pub fn iters_to_accuracy(&self, threshold: f64) -> Option<usize> {
        self.points.iter().find(|p| p.accuracy <= threshold).map(|p| p.iter)
    }

    /// Communication units spent to reach `threshold` accuracy.
    pub fn comm_to_accuracy(&self, threshold: f64) -> Option<f64> {
        self.points.iter().find(|p| p.accuracy <= threshold).map(|p| p.comm_units)
    }

    /// Simulated time to reach `threshold` accuracy.
    pub fn time_to_accuracy(&self, threshold: f64) -> Option<f64> {
        self.points.iter().find(|p| p.accuracy <= threshold).map(|p| p.sim_time)
    }

    /// Export as a JSON object with parallel arrays (plot-friendly).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .str("label", &self.label)
            .field("iter", Json::arr_f64(self.points.iter().map(|p| p.iter as f64)))
            .field("comm_units", Json::arr_f64(self.points.iter().map(|p| p.comm_units)))
            .field("sim_time", Json::arr_f64(self.points.iter().map(|p| p.sim_time)))
            .field("accuracy", Json::arr_f64(self.points.iter().map(|p| p.accuracy)))
            .field("test_mse", Json::arr_f64(self.points.iter().map(|p| p.test_mse)))
            .build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(iter: usize, acc: f64) -> TracePoint {
        TracePoint { iter, comm_units: iter as f64, sim_time: iter as f64 * 0.1, accuracy: acc, test_mse: acc * 2.0 }
    }

    #[test]
    fn thresholds() {
        let mut t = Trace::new("x");
        t.push(pt(1, 1.0));
        t.push(pt(10, 0.1));
        t.push(pt(100, 0.01));
        assert_eq!(t.iters_to_accuracy(0.5), Some(10));
        assert_eq!(t.comm_to_accuracy(0.05), Some(100.0));
        assert_eq!(t.iters_to_accuracy(0.001), None);
        assert!((t.final_accuracy() - 0.01).abs() < 1e-15);
    }

    #[test]
    fn json_shape() {
        let mut t = Trace::new("sI-ADMM");
        t.push(pt(1, 0.9));
        let s = t.to_json().to_string();
        assert!(s.contains("\"label\":\"sI-ADMM\""));
        assert!(s.contains("\"accuracy\":[0.9]"));
    }
}
