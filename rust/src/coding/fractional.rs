//! Fractional repetition scheme (Tandon et al. §III-A).
//!
//! K ECNs are divided into `K/(S+1)` groups of `S+1`. The K base
//! partitions are divided into the same number of blocks of `S+1`
//! consecutive partitions; every ECN in group `g` replicates block `g`
//! and sends the plain sum of its per-partition gradients. Any
//! `R = K − S` responders must contain at least one member of every group
//! (a group has S+1 members and only S can be missing), so decoding is:
//! pick one responder per group, add them up.

use super::GradientCode;
use crate::error::{Error, Result};
use crate::linalg::Matrix;

/// Fractional repetition code. Requires `(S+1) | K`.
#[derive(Clone, Debug)]
pub struct FractionalRepetition {
    k: usize,
    s: usize,
    assignments: Vec<Vec<usize>>,
}

impl FractionalRepetition {
    /// Build for K ECNs tolerating S stragglers; `(S+1)` must divide K.
    pub fn new(k: usize, s: usize) -> Result<Self> {
        if k == 0 || s >= k {
            return Err(Error::Coding(format!("fractional: bad (k={k}, s={s})")));
        }
        if k % (s + 1) != 0 {
            return Err(Error::Coding(format!(
                "fractional repetition needs (S+1)|K, got K={k}, S={s}"
            )));
        }
        let group_size = s + 1;
        let assignments = (0..k)
            .map(|j| {
                let g = j / group_size;
                // Block g: partitions [g*(S+1), (g+1)*(S+1)).
                (g * group_size..(g + 1) * group_size).collect()
            })
            .collect();
        Ok(Self { k, s, assignments })
    }

    /// The group index of an ECN.
    pub fn group_of(&self, ecn: usize) -> usize {
        ecn / (self.s + 1)
    }

    /// Number of groups `K/(S+1)`.
    pub fn num_groups(&self) -> usize {
        self.k / (self.s + 1)
    }
}

impl GradientCode for FractionalRepetition {
    fn k(&self) -> usize {
        self.k
    }

    fn s(&self) -> usize {
        self.s
    }

    fn assignment(&self, ecn: usize) -> &[usize] {
        &self.assignments[ecn]
    }

    fn encode(&self, _ecn: usize, partial: &[&Matrix]) -> Matrix {
        assert_eq!(partial.len(), self.s + 1);
        let mut out = partial[0].clone();
        for g in &partial[1..] {
            out += *g;
        }
        out
    }

    fn encode_into(&self, ecn: usize, parts: &[Matrix], out: &mut Matrix) {
        // Same accumulation order as `encode`: block head first, then
        // the remaining block members in ascending partition order.
        let support = &self.assignments[ecn];
        out.copy_from(&parts[support[0]]);
        for &p in &support[1..] {
            *out += &parts[p];
        }
    }

    fn decode(&self, arrived: &[(usize, Matrix)]) -> Result<Matrix> {
        let groups = self.num_groups();
        let mut have: Vec<Option<&Matrix>> = vec![None; groups];
        for (ecn, g) in arrived {
            let grp = self.group_of(*ecn);
            if have[grp].is_none() {
                have[grp] = Some(g);
            }
        }
        let mut sum: Option<Matrix> = None;
        for (grp, rep) in have.iter().enumerate() {
            let rep = rep.ok_or_else(|| {
                Error::Coding(format!("fractional: no responder from group {grp}"))
            })?;
            match &mut sum {
                None => sum = Some(rep.clone()),
                Some(s) => *s += rep,
            }
        }
        sum.ok_or_else(|| Error::Coding("fractional: zero groups".into()))
    }

    fn name(&self) -> &'static str {
        "fractional"
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::check_recovers_sum;
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};
    use crate::util::prop::property;

    #[test]
    fn divisibility_enforced() {
        assert!(FractionalRepetition::new(6, 1).is_ok()); // groups of 2
        assert!(FractionalRepetition::new(6, 2).is_ok()); // groups of 3
        assert!(FractionalRepetition::new(6, 3).is_err()); // 4 ∤ 6
        assert!(FractionalRepetition::new(4, 4).is_err()); // s >= k
    }

    #[test]
    fn replication_factor_is_s_plus_1() {
        let code = FractionalRepetition::new(6, 2).unwrap();
        for j in 0..6 {
            assert_eq!(code.assignment(j).len(), 3);
        }
        // Group members share the same block.
        assert_eq!(code.assignment(0), code.assignment(1));
        assert_eq!(code.assignment(0), code.assignment(2));
        assert_ne!(code.assignment(0), code.assignment(3));
    }

    #[test]
    fn recovers_from_any_r_subset() {
        let mut rng = Xoshiro256pp::seed_from_u64(62);
        for &(k, s) in &[(2, 1), (4, 1), (6, 1), (6, 2), (8, 3), (9, 2), (12, 3)] {
            let code = FractionalRepetition::new(k, s).unwrap();
            check_recovers_sum(&code, &mut rng);
        }
    }

    #[test]
    fn worst_case_group_wipeout_detected() {
        // If a whole group is missing (more than S stragglers), decode
        // must fail rather than return a wrong sum.
        let code = FractionalRepetition::new(4, 1).unwrap();
        let g = Matrix::full(2, 1, 1.0);
        // Only responders from group 0 (ECNs 0,1): group 1 missing.
        let arrived = vec![(0usize, g.clone()), (1usize, g.clone())];
        assert!(code.decode(&arrived).is_err());
    }

    #[test]
    fn property_random_configs() {
        property("fractional decodes", 20, |rng| {
            let s = rng.below(3) as usize;
            let groups = 1 + rng.below(4) as usize;
            let k = groups * (s + 1);
            if s >= k {
                return;
            }
            let code = FractionalRepetition::new(k, s).unwrap();
            check_recovers_sum(&code, rng);
        });
    }
}
