//! PJRT-backed engine: loads the AOT HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Interchange format is HLO **text** (not serialized proto): jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example and
//! DESIGN.md). One executable is compiled per shape and cached, so the
//! steady-state request path is: build literals → execute → read back.
//!
//! The real engine needs the external `xla` crate and is therefore
//! compiled only under the `pjrt-xla` feature (the offline build
//! environment cannot resolve the dependency). Without the feature,
//! [`PjrtEngine`] is a stub with the same API whose every call takes the
//! native-fallback path (or errors in strict mode), so callers and tests
//! compile and behave identically when no artifacts are present.

use super::{Engine, NativeEngine};
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use std::path::{Path, PathBuf};

/// Canonical artifact file name for a gradient kernel of shape
/// `(m, p, d)` or the fused step of shape `(p, d)`.
pub fn artifact_name(kind: &str, dims: &[usize]) -> String {
    let dims: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
    format!("{kind}_{}.hlo.txt", dims.join("x"))
}

#[cfg(feature = "pjrt-xla")]
mod real {
    use super::*;
    use std::collections::HashMap;

    /// Engine that executes the L1/L2 AOT artifacts via PJRT.
    pub struct PjrtEngine {
        client: xla::PjRtClient,
        dir: PathBuf,
        grad_exes: HashMap<(usize, usize, usize), xla::PjRtLoadedExecutable>,
        step_exes: HashMap<(usize, usize), xla::PjRtLoadedExecutable>,
        fallback: NativeEngine,
        /// When false (default) missing artifacts fall back to the native
        /// engine; when true they are hard errors (used by integration
        /// tests to prove the PJRT path really ran).
        strict: bool,
        /// Calls served by PJRT vs native fallback (observability).
        pub pjrt_calls: u64,
        pub native_calls: u64,
    }

    impl PjrtEngine {
        /// Create over an artifacts directory (usually `artifacts/`).
        pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(Error::runtime)?;
            Ok(Self {
                client,
                dir: artifacts_dir.as_ref().to_path_buf(),
                grad_exes: HashMap::new(),
                step_exes: HashMap::new(),
                fallback: NativeEngine::new(),
                strict: false,
                pjrt_calls: 0,
                native_calls: 0,
            })
        }

        /// Error (instead of native fallback) when an artifact is missing.
        pub fn strict(mut self) -> Self {
            self.strict = true;
            self
        }

        fn load(&self, name: &str) -> Result<xla::PjRtLoadedExecutable> {
            let path = self.dir.join(name);
            if !path.exists() {
                return Err(Error::Runtime(format!("artifact not found: {}", path.display())));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
            )
            .map_err(Error::runtime)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            self.client.compile(&comp).map_err(Error::runtime)
        }

        fn literal_of(m: &Matrix) -> Result<xla::Literal> {
            xla::Literal::vec1(m.as_slice())
                .reshape(&[m.rows() as i64, m.cols() as i64])
                .map_err(Error::runtime)
        }

        fn matrix_of(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Matrix> {
            let v = lit.to_vec::<f64>().map_err(Error::runtime)?;
            Matrix::from_vec(rows, cols, v)
        }

        /// Whether a gradient artifact for this shape is available (loaded
        /// or on disk).
        pub fn has_grad_artifact(&self, m: usize, p: usize, d: usize) -> bool {
            self.grad_exes.contains_key(&(m, p, d))
                || self.dir.join(artifact_name("grad", &[m, p, d])).exists()
        }
    }

    impl Engine for PjrtEngine {
        fn grad_batch(&mut self, o: &Matrix, t: &Matrix, x: &Matrix) -> Result<Matrix> {
            let key = (o.rows(), x.rows(), x.cols());
            if !self.grad_exes.contains_key(&key) {
                match self.load(&artifact_name("grad", &[key.0, key.1, key.2])) {
                    Ok(exe) => {
                        self.grad_exes.insert(key, exe);
                    }
                    Err(e) if self.strict => return Err(e),
                    Err(_) => {
                        self.native_calls += 1;
                        return self.fallback.grad_batch(o, t, x);
                    }
                }
            }
            let exe = &self.grad_exes[&key];
            let args = [Self::literal_of(o)?, Self::literal_of(t)?, Self::literal_of(x)?];
            let result = exe
                .execute::<xla::Literal>(&args)
                .map_err(Error::runtime)?[0][0]
                .to_literal_sync()
                .map_err(Error::runtime)?;
            // aot.py lowers with return_tuple=True → 1-tuple.
            let out = result.to_tuple1().map_err(Error::runtime)?;
            self.pjrt_calls += 1;
            Self::matrix_of(&out, key.1, key.2)
        }

        fn admm_step(
            &mut self,
            x: &Matrix,
            y: &Matrix,
            z: &Matrix,
            g: &Matrix,
            rho: f64,
            tau: f64,
            gamma: f64,
            n: usize,
        ) -> Result<(Matrix, Matrix, Matrix)> {
            let key = (x.rows(), x.cols());
            if !self.step_exes.contains_key(&key) {
                match self.load(&artifact_name("step", &[key.0, key.1])) {
                    Ok(exe) => {
                        self.step_exes.insert(key, exe);
                    }
                    Err(e) if self.strict => return Err(e),
                    Err(_) => {
                        self.native_calls += 1;
                        return Ok(super::super::native_admm_step(x, y, z, g, rho, tau, gamma, n));
                    }
                }
            }
            let exe = &self.step_exes[&key];
            let args = [
                Self::literal_of(x)?,
                Self::literal_of(y)?,
                Self::literal_of(z)?,
                Self::literal_of(g)?,
                xla::Literal::scalar(rho),
                xla::Literal::scalar(tau),
                xla::Literal::scalar(gamma),
                xla::Literal::scalar(1.0 / n as f64),
            ];
            let result = exe
                .execute::<xla::Literal>(&args)
                .map_err(Error::runtime)?[0][0]
                .to_literal_sync()
                .map_err(Error::runtime)?;
            let (lx, ly, lz) = result.to_tuple3().map_err(Error::runtime)?;
            self.pjrt_calls += 1;
            Ok((
                Self::matrix_of(&lx, key.0, key.1)?,
                Self::matrix_of(&ly, key.0, key.1)?,
                Self::matrix_of(&lz, key.0, key.1)?,
            ))
        }

        fn grad_batch_range(
            &mut self,
            o_full: &Matrix,
            t_full: &Matrix,
            lo: usize,
            hi: usize,
            x: &Matrix,
            out: &mut Matrix,
        ) -> Result<()> {
            let (m, p, d) = (hi - lo, x.rows(), x.cols());
            // Only materialize the row block when a PJRT artifact will
            // actually consume it (literals need an owned copy anyway);
            // otherwise pass the range straight through to the native
            // engine's zero-copy fused kernel instead of slicing first.
            if self.has_grad_artifact(m, p, d) {
                let o = o_full.slice_rows(lo, hi);
                let t = t_full.slice_rows(lo, hi);
                let g = self.grad_batch(&o, &t, x)?;
                out.copy_from(&g);
                return Ok(());
            }
            if self.strict {
                return Err(Error::Runtime(format!(
                    "artifact not found: {}",
                    self.dir.join(artifact_name("grad", &[m, p, d])).display()
                )));
            }
            self.native_calls += 1;
            self.fallback.grad_batch_range(o_full, t_full, lo, hi, x, out)
        }

        fn set_shard_threads(&mut self, threads: usize) {
            self.fallback.set_shard_threads(threads);
        }

        fn set_kernel_tier(&mut self, tier: crate::linalg::KernelTier) {
            self.fallback.set_kernel_tier(tier);
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }
}

#[cfg(not(feature = "pjrt-xla"))]
mod stub {
    use super::*;

    /// Offline stand-in for the PJRT engine: artifacts can never be
    /// loaded (there is no PJRT client), so every call is a native
    /// fallback — or an error in strict mode. API-compatible with the
    /// real engine so the rest of the crate compiles unchanged.
    pub struct PjrtEngine {
        dir: PathBuf,
        fallback: NativeEngine,
        strict: bool,
        /// Calls served by PJRT vs native fallback (observability).
        pub pjrt_calls: u64,
        pub native_calls: u64,
    }

    impl PjrtEngine {
        /// Create over an artifacts directory (usually `artifacts/`).
        pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
            Ok(Self {
                dir: artifacts_dir.as_ref().to_path_buf(),
                fallback: NativeEngine::new(),
                strict: false,
                pjrt_calls: 0,
                native_calls: 0,
            })
        }

        /// Error (instead of native fallback) when an artifact is missing.
        pub fn strict(mut self) -> Self {
            self.strict = true;
            self
        }

        fn unavailable(&self) -> Error {
            Error::Runtime(
                "PJRT support not compiled in (build with --features pjrt-xla)".into(),
            )
        }

        /// Whether a gradient artifact for this shape is on disk (the
        /// stub can see files; it just cannot execute them).
        pub fn has_grad_artifact(&self, m: usize, p: usize, d: usize) -> bool {
            self.dir.join(artifact_name("grad", &[m, p, d])).exists()
        }
    }

    impl Engine for PjrtEngine {
        fn grad_batch(&mut self, o: &Matrix, t: &Matrix, x: &Matrix) -> Result<Matrix> {
            if self.strict {
                return Err(self.unavailable());
            }
            self.native_calls += 1;
            self.fallback.grad_batch(o, t, x)
        }

        fn grad_batch_range(
            &mut self,
            o_full: &Matrix,
            t_full: &Matrix,
            lo: usize,
            hi: usize,
            x: &Matrix,
            out: &mut Matrix,
        ) -> Result<()> {
            if self.strict {
                return Err(self.unavailable());
            }
            // Delegate to the native engine's own override so the stub
            // keeps the zero-copy hot path (the trait default would
            // slice + allocate per call).
            self.native_calls += 1;
            self.fallback.grad_batch_range(o_full, t_full, lo, hi, x, out)
        }

        fn admm_step(
            &mut self,
            x: &Matrix,
            y: &Matrix,
            z: &Matrix,
            g: &Matrix,
            rho: f64,
            tau: f64,
            gamma: f64,
            n: usize,
        ) -> Result<(Matrix, Matrix, Matrix)> {
            if self.strict {
                return Err(self.unavailable());
            }
            self.native_calls += 1;
            Ok(super::super::native_admm_step(x, y, z, g, rho, tau, gamma, n))
        }

        fn set_shard_threads(&mut self, threads: usize) {
            self.fallback.set_shard_threads(threads);
        }

        fn set_kernel_tier(&mut self, tier: crate::linalg::KernelTier) {
            self.fallback.set_kernel_tier(tier);
        }

        fn name(&self) -> &'static str {
            "pjrt-stub(native)"
        }
    }
}

#[cfg(feature = "pjrt-xla")]
pub use real::PjrtEngine;
#[cfg(not(feature = "pjrt-xla"))]
pub use stub::PjrtEngine;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names() {
        assert_eq!(artifact_name("grad", &[8, 3, 1]), "grad_8x3x1.hlo.txt");
        assert_eq!(artifact_name("step", &[64, 10]), "step_64x10.hlo.txt");
    }

    #[cfg(not(feature = "pjrt-xla"))]
    #[test]
    fn stub_falls_back_to_native() {
        let mut eng = PjrtEngine::new("artifacts-nonexistent").unwrap();
        let o = Matrix::full(4, 3, 1.0);
        let t = Matrix::full(4, 2, 2.0);
        let x = Matrix::zeros(3, 2);
        let g = eng.grad_batch(&o, &t, &x).unwrap();
        assert_eq!(g.shape(), (3, 2));
        assert_eq!(eng.native_calls, 1);
        assert_eq!(eng.pjrt_calls, 0);
        let mut strict = PjrtEngine::new("artifacts-nonexistent").unwrap().strict();
        assert!(strict.grad_batch(&o, &t, &x).is_err());
    }
}
