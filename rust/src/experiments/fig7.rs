//! Fig. 7 (extension) — the communication frontier: accuracy vs
//! cumulative wire bytes across the compressor zoo, coded vs uncoded.
//!
//! The paper's Fig. 3 counts abstract communication *units*; this
//! experiment asks the §I question directly in **bytes**: how much
//! accuracy does each token codec buy per byte actually on the wire?
//! Both arms (uncoded sI-ADMM at M̄ and csI-ADMM at M = (S+1)·M̄, equal
//! effective batch per Eq. 22) run the full zoo — exact f64 tokens,
//! `f32`, stochastic quantization at 8 and 4 bits, and the biased
//! sparsifiers `topk`/`randk` with and without error feedback — on the
//! `[sweep] compress` axis, seed-averaged.
//!
//! Two headline shapes come out:
//!
//! * a **monotone bytes-vs-accuracy Pareto frontier**: ranking the
//!   codecs by cumulative wire bytes, the undominated ones trade bytes
//!   for accuracy monotonically ([`pareto_frontier`]);
//! * **error feedback recovering convergence**: the consensus token z
//!   is *persistent incremental state* (`z⁺ = z + Δ/N`), so a biased
//!   sparsifier that zeroes most coordinates on every hop freezes the
//!   dropped support and the run stalls — the `+ef` variants carry the
//!   compression residual across transfers and converge again.

use super::{load_dataset, write_traces, ROOT_SEED};
use crate::coding::SchemeKind;
use crate::comm::CodecSpec;
use crate::coordinator::{Algorithm, RunConfig};
use crate::data::DatasetName;
use crate::error::{Error, Result};
use crate::metrics::Trace;
use crate::runtime::EngineFactory;
use crate::sweep::{default_workers, mean_trace, run_sweep, SweepSpec};
use crate::util::table::{fnum, Table};

/// The codec tokens swept (the compressor zoo; parsed by
/// [`CodecSpec::parse`]).
pub const ZOO: [&str; 8] =
    ["identity", "f32", "q8", "q4", "topk", "topk+ef", "randk", "randk+ef"];

/// Tolerated stragglers of the coded arm.
const S_DESIGN: usize = 1;
/// Effective mini-batch M̄ shared by both arms.
const M_BAR: usize = 8;

fn base_cfg(quick: bool) -> RunConfig {
    RunConfig {
        n_agents: 6,
        k_ecn: 2,
        rho: 0.2,
        // Quick keeps a larger share of the budget than the usual /8:
        // the EF-recovery gap needs the exact/EF arms to pull clearly
        // away from the biased sparsifiers' stall floor, and the runs
        // are tiny (6 agents, K=2).
        max_iters: if quick { 1_600 } else { 4_800 },
        eval_every: 25,
        seed: ROOT_SEED ^ 7,
        ..Default::default()
    }
}

/// One codec's paired result.
#[derive(Clone, Debug)]
pub struct CodecComparison {
    /// Codec token (`"q8"`, `"topk+ef"`, …).
    pub codec: String,
    /// Final cumulative wire bytes of the coded arm (seed mean).
    pub coded_bytes: f64,
    /// Final Eq. 23 accuracy of the coded arm (seed mean).
    pub coded_accuracy: f64,
    /// Final cumulative wire bytes of the uncoded arm (seed mean).
    pub uncoded_bytes: f64,
    /// Final Eq. 23 accuracy of the uncoded arm (seed mean).
    pub uncoded_accuracy: f64,
}

/// One arm of the comparison: sweep the compress axis for a fixed
/// algorithm/minibatch and return one seed-averaged trace per codec,
/// in [`ZOO`] order.
fn zoo_arm(cfg: RunConfig, quick: bool, engines: &dyn EngineFactory) -> Result<Vec<Trace>> {
    let ds = load_dataset(DatasetName::Synthetic, quick);
    let runs = if quick { 2 } else { 5 };
    let seeds: Vec<u64> = (0..runs).map(|r| ROOT_SEED ^ 7 ^ ((r as u64) << 8)).collect();
    let zoo: Vec<CodecSpec> = ZOO
        .iter()
        .map(|t| CodecSpec::parse(t).expect("fig7 zoo tokens are valid"))
        .collect();
    let spec = SweepSpec::new(cfg).compress(zoo).seeds(seeds);
    let result = run_sweep(&spec, &ds, default_workers(), engines)?;
    let mut traces = vec![];
    for cell in result.cells() {
        let refs: Vec<&Trace> = cell.iter().map(|j| &j.trace).collect();
        let mut avg = mean_trace(&refs)?;
        avg.label = format!(
            "{} cx={}",
            cell[0].job.cfg.algo.label(),
            cell[0].job.cfg.comm.as_str()
        );
        traces.push(avg);
    }
    Ok(traces)
}

/// The bytes-vs-accuracy Pareto frontier of a point set: undominated
/// `(bytes, accuracy)` pairs, returned sorted by ascending bytes —
/// along which accuracy is strictly decreasing (monotone by
/// construction; lower accuracy = better, Eq. 23). Ties on bytes keep
/// the more accurate point.
pub fn pareto_frontier(points: &[(String, f64, f64)]) -> Vec<(String, f64, f64)> {
    let mut sorted: Vec<&(String, f64, f64)> = points.iter().collect();
    sorted.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.2.total_cmp(&b.2)));
    let mut frontier: Vec<(String, f64, f64)> = vec![];
    for p in sorted {
        if frontier.last().is_none_or(|last| p.2 < last.2) {
            frontier.push(p.clone());
        }
    }
    frontier
}

/// Run Fig. 7: the compressor-zoo frontier, coded vs uncoded. Returns
/// the per-codec comparisons (the experiment's headline numbers), in
/// [`ZOO`] order.
pub fn run(quick: bool, engines: &dyn EngineFactory) -> Result<Vec<CodecComparison>> {
    let uncoded = zoo_arm(
        RunConfig { algo: Algorithm::SIAdmm, minibatch: M_BAR, ..base_cfg(quick) },
        quick,
        engines,
    )?;
    let coded = zoo_arm(
        RunConfig {
            algo: Algorithm::CsIAdmm(SchemeKind::Cyclic),
            s_tolerated: S_DESIGN,
            minibatch: (S_DESIGN + 1) * M_BAR,
            ..base_cfg(quick)
        },
        quick,
        engines,
    )?;

    let missing = || Error::Runtime("fig7: arm trace ended empty".into());
    let mut comparisons = vec![];
    let mut t = Table::new(
        "Fig. 7 — accuracy vs cumulative wire bytes per token codec (synthetic, S=1)",
        &["codec", "wire kB (coded)", "acc coded", "acc uncoded"],
    );
    for ((token, unc), cod) in ZOO.iter().zip(&uncoded).zip(&coded) {
        let c = CodecComparison {
            codec: token.to_string(),
            coded_bytes: cod.final_comm_bytes().ok_or_else(missing)?,
            coded_accuracy: cod.final_accuracy(),
            uncoded_bytes: unc.final_comm_bytes().ok_or_else(missing)?,
            uncoded_accuracy: unc.final_accuracy(),
        };
        t.row(&[
            c.codec.clone(),
            fnum(c.coded_bytes / 1e3),
            fnum(c.coded_accuracy),
            fnum(c.uncoded_accuracy),
        ]);
        comparisons.push(c);
    }
    t.print();

    // The Pareto frontier over the coded arm: which codecs actually
    // buy accuracy per byte.
    let points: Vec<(String, f64, f64)> = comparisons
        .iter()
        .map(|c| (c.codec.clone(), c.coded_bytes, c.coded_accuracy))
        .collect();
    let frontier = pareto_frontier(&points);
    let mut ft = Table::new(
        "Fig. 7 frontier — undominated codecs by ascending wire bytes",
        &["codec", "wire kB", "accuracy"],
    );
    for (codec, bytes, acc) in &frontier {
        ft.row(&[codec.clone(), fnum(bytes / 1e3), fnum(*acc)]);
    }
    ft.print();
    println!(
        "error feedback: topk {} -> topk+ef {}, randk {} -> randk+ef {}",
        fnum(comparisons[4].coded_accuracy),
        fnum(comparisons[5].coded_accuracy),
        fnum(comparisons[6].coded_accuracy),
        fnum(comparisons[7].coded_accuracy),
    );

    let mut traces: Vec<Trace> = uncoded.into_iter().chain(coded).collect();
    print!(
        "{}",
        crate::util::chart::chart_traces(
            "Fig. 7 accuracy vs cumulative wire bytes",
            "wire bytes",
            &traces,
            |p| p.comm_bytes,
        )
    );
    // Stamp codec labels so the JSON export carries the byte columns
    // for every series (including the identity baselines, which would
    // otherwise serialize in the legacy unit-only shape).
    for trace in &mut traces {
        if trace.codec.is_none() {
            trace.codec = Some("identity".into());
        }
    }
    write_traces("fig7_comm_frontier", &traces)?;
    Ok(comparisons)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeEngineFactory;

    /// The acceptance properties: the frontier spans ≥ 4 codecs and is
    /// monotone, and error feedback recovers convergence for the
    /// biased sparsifiers.
    #[test]
    fn frontier_is_monotone_and_error_feedback_recovers() {
        let comparisons = run(true, &NativeEngineFactory).unwrap();
        assert!(comparisons.len() >= 4, "zoo must span >= 4 codecs");

        let points: Vec<(String, f64, f64)> = comparisons
            .iter()
            .map(|c| (c.codec.clone(), c.coded_bytes, c.coded_accuracy))
            .collect();
        let frontier = pareto_frontier(&points);
        assert!(frontier.len() >= 2, "frontier collapsed: {frontier:?}");
        for w in frontier.windows(2) {
            assert!(w[0].1 < w[1].1, "frontier bytes not increasing: {frontier:?}");
            assert!(w[1].2 < w[0].2, "frontier accuracy not decreasing: {frontier:?}");
        }

        // Error feedback rescues the biased sparsifiers decisively:
        // the persistent z-state means plain topk/randk stall, while
        // the +ef variants keep converging.
        let by_name = |n: &str| comparisons.iter().find(|c| c.codec == n).unwrap();
        for (plain, ef) in [("topk", "topk+ef"), ("randk", "randk+ef")] {
            let (p, e) = (by_name(plain), by_name(ef));
            assert!(
                e.coded_accuracy < 0.75 * p.coded_accuracy,
                "{ef} must recover convergence: {} !< 0.75 * {}",
                e.coded_accuracy,
                p.coded_accuracy
            );
            assert!(
                e.uncoded_accuracy < 0.75 * p.uncoded_accuracy,
                "{ef} (uncoded arm) must recover convergence: {} !< 0.75 * {}",
                e.uncoded_accuracy,
                p.uncoded_accuracy
            );
        }
        // And the exact-token baseline converges in this budget (the
        // frontier's high-byte anchor is meaningful).
        assert!(by_name("identity").coded_accuracy < 0.8);
    }

    #[test]
    fn pareto_frontier_drops_dominated_points() {
        let pts = vec![
            ("a".to_string(), 100.0, 0.5),
            ("b".to_string(), 200.0, 0.1),  // frontier
            ("c".to_string(), 150.0, 0.6),  // dominated by a
            ("d".to_string(), 50.0, 0.9),   // frontier (cheapest)
            ("e".to_string(), 300.0, 0.2),  // dominated by b
            ("f".to_string(), 100.0, 0.45), // ties a on bytes, better acc
        ];
        let f = pareto_frontier(&pts);
        let names: Vec<&str> = f.iter().map(|p| p.0.as_str()).collect();
        assert_eq!(names, vec!["d", "f", "b"]);
    }
}
