//! Scratch arena for the engine hot path.
//!
//! A [`Workspace`] owns the named, shape-keyed scratch buffers the
//! gradient hot loop needs — the residual tile of the fused kernel, the
//! full residual of `grad_batch`, the evaluation residual of the
//! test-loss path, and the blocked-solver panel arena — so steady-state
//! rounds perform **zero heap allocation**: a buffer is (re)allocated only when its requested
//! shape changes, and `allocations()` counts exactly those events,
//! which is what the reuse tests assert.

use crate::linalg::{Matrix, SolveScratch};

/// Named scratch buffers with an allocation counter.
///
/// Each accessor returns the buffer resized to the requested shape
/// (contents unspecified — callers overwrite). Requesting the same
/// shape again returns the same storage without touching the heap.
pub struct Workspace {
    /// Residual tile for the fused range-gradient kernel.
    resid_tile: Matrix,
    /// Full residual for the whole-batch `grad_batch` path.
    resid_full: Matrix,
    /// Evaluation residual for the test-loss path.
    eval: Matrix,
    /// Panel/update scratch for the blocked solvers
    /// ([`crate::linalg::cholesky_factor_blocked_with`]).
    solve: SolveScratch,
    /// Number of buffer (re)allocations since construction.
    allocations: u64,
}

impl Workspace {
    /// Empty arena; the first request of each buffer allocates it.
    pub fn new() -> Self {
        Self {
            resid_tile: Matrix::zeros(0, 0),
            resid_full: Matrix::zeros(0, 0),
            eval: Matrix::zeros(0, 0),
            solve: SolveScratch::new(),
            allocations: 0,
        }
    }

    fn ensure(buf: &mut Matrix, rows: usize, cols: usize, allocations: &mut u64) {
        if buf.shape() != (rows, cols) {
            *buf = Matrix::zeros(rows, cols);
            *allocations += 1;
        }
    }

    /// Residual-tile buffer (`rows × cols`) for
    /// [`crate::linalg::fused_ls_grad_range`].
    pub fn resid_tile(&mut self, rows: usize, cols: usize) -> &mut Matrix {
        Self::ensure(&mut self.resid_tile, rows, cols, &mut self.allocations);
        &mut self.resid_tile
    }

    /// Full-residual buffer (`rows × cols`) for the whole-batch path.
    pub fn resid_full(&mut self, rows: usize, cols: usize) -> &mut Matrix {
        Self::ensure(&mut self.resid_full, rows, cols, &mut self.allocations);
        &mut self.resid_full
    }

    /// Evaluation-residual buffer (`rows × cols`) for the test-loss
    /// path ([`crate::metrics::test_mse_ws`]).
    pub fn eval(&mut self, rows: usize, cols: usize) -> &mut Matrix {
        Self::ensure(&mut self.eval, rows, cols, &mut self.allocations);
        &mut self.eval
    }

    /// Blocked-solver scratch arena ([`SolveScratch`] keeps its own
    /// reallocate-only-on-shape-change panels, so repeated factors of
    /// the same-size Gram matrix stay allocation-free).
    pub fn solve(&mut self) -> &mut SolveScratch {
        &mut self.solve
    }

    /// Number of buffer (re)allocations since construction. Constant
    /// across calls ⇔ the steady state allocates nothing.
    pub fn allocations(&self) -> u64 {
        self.allocations
    }
}

impl Default for Workspace {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The zero-allocation contract: repeated same-shape requests never
    /// touch the heap; only a shape change does.
    #[test]
    fn steady_state_allocates_nothing() {
        let mut ws = Workspace::new();
        ws.resid_tile(8, 1);
        ws.resid_full(16, 3);
        ws.eval(100, 1);
        let warm = ws.allocations();
        assert_eq!(warm, 3);
        for _ in 0..50 {
            ws.resid_tile(8, 1).fill_zero();
            ws.resid_full(16, 3).fill_zero();
            ws.eval(100, 1).fill_zero();
        }
        assert_eq!(ws.allocations(), warm, "steady state must not reallocate");
        ws.resid_tile(9, 1);
        assert_eq!(ws.allocations(), warm + 1, "shape change is one allocation");
    }

    /// Buffers are independent: resizing one leaves the others alone.
    #[test]
    fn buffers_are_independent() {
        let mut ws = Workspace::new();
        ws.resid_tile(4, 2).fill_zero();
        ws.eval(7, 1).fill_zero();
        let before = ws.allocations();
        ws.resid_tile(5, 2);
        assert_eq!(ws.eval(7, 1).shape(), (7, 1));
        assert_eq!(ws.allocations(), before + 1);
    }
}
