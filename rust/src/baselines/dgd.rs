//! Decentralized gradient descent (DGD, [6]):
//! `x_i^{k+1} = Σ_j W_ij x_j^k − α^k ∇f_i(x_i^k)` with Metropolis
//! weights and a diminishing step `α^k = α₀/√k` (required for exact
//! convergence of DGD).

use super::GossipAlgorithm;
use crate::error::Result;
use crate::graph::Topology;
use crate::linalg::Matrix;
use crate::problem::{LeastSquares, Objective};

/// DGD baseline.
pub struct Dgd {
    /// Initial step size α₀.
    pub alpha0: f64,
    /// Cached mixing weights (built on first step).
    w: Option<Matrix>,
    grad_buf: Option<Matrix>,
}

impl Dgd {
    /// New DGD with step α₀.
    pub fn new(alpha0: f64) -> Self {
        Self { alpha0, w: None, grad_buf: None }
    }
}

impl GossipAlgorithm for Dgd {
    fn label(&self) -> String {
        "DGD".into()
    }

    fn step(
        &mut self,
        k: usize,
        topo: &Topology,
        objs: &[LeastSquares],
        xs: &mut [Matrix],
    ) -> Result<()> {
        if self.w.is_none() {
            self.w = Some(topo.metropolis_weights());
        }
        let w = self.w.as_ref().unwrap();
        let n = xs.len();
        let (p, d) = xs[0].shape();
        if self.grad_buf.is_none() {
            self.grad_buf = Some(Matrix::zeros(p, d));
        }
        let alpha = self.alpha0 / (k as f64).sqrt();
        let mut next: Vec<Matrix> = Vec::with_capacity(n);
        let g = self.grad_buf.as_mut().unwrap();
        for i in 0..n {
            // Mix: Σ_j W_ij x_j (only self + neighbors are nonzero).
            let mut xi = xs[i].scaled(w[(i, i)]);
            for &j in topo.neighbors(i) {
                xi.add_scaled(w[(i, j)], &xs[j]);
            }
            objs[i].grad(&xs[i], g);
            xi.add_scaled(-alpha, g);
            next.push(xi);
        }
        xs.clone_from_slice(&next);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::harness::{comparable_setup, GossipHarness};
    use super::*;
    use crate::data::synthetic_small;

    #[test]
    fn dgd_converges_towards_optimum() {
        let ds = synthetic_small(600, 60, 0.05, 111);
        let (topo, objs, xstar) = comparable_setup(&ds, 5, 0.6, 3).unwrap();
        let h = GossipHarness {
            topo,
            response: Default::default(),
            comm: Default::default(),
            max_iters: 800,
            eval_every: 40,
            seed: 3,
        };
        let trace = h.run(Dgd::new(0.3), &objs, &xstar, &ds.test).unwrap();
        let acc = trace.final_accuracy();
        assert!(acc < 0.25, "DGD should reduce relative error, got {acc}");
        assert!(trace.points[0].accuracy > acc);
    }

    #[test]
    fn dgd_charges_2e_units_per_iteration() {
        let ds = synthetic_small(300, 30, 0.05, 112);
        let (topo, objs, xstar) = comparable_setup(&ds, 5, 0.6, 4).unwrap();
        let links = topo.num_edges();
        let h = GossipHarness {
            topo,
            response: Default::default(),
            comm: Default::default(),
            max_iters: 10,
            eval_every: 10,
            seed: 4,
        };
        let trace = h.run(Dgd::new(0.1), &objs, &xstar, &ds.test).unwrap();
        assert_eq!(trace.points.last().unwrap().comm_units, (10 * 2 * links) as f64);
    }
}
