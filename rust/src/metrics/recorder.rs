//! Per-run trace recording and JSON export.

use crate::util::json::Json;

/// One evaluation point along a run.
#[derive(Clone, Debug, PartialEq)]
pub struct TracePoint {
    /// Iteration k.
    pub iter: usize,
    /// Cumulative communication units.
    pub comm_units: f64,
    /// Cumulative exact wire bytes (header + payload per encoded token
    /// transfer, per hop) — the byte book of
    /// [`crate::comm::WireLedger`]. Zero for harnesses that only count
    /// units (the gossip baselines).
    pub comm_bytes: f64,
    /// Cumulative simulated running time (s).
    pub sim_time: f64,
    /// Relative-error accuracy (Eq. 23).
    pub accuracy: f64,
    /// Test MSE at the consensus variable.
    pub test_mse: f64,
}

/// A labelled series of trace points (one run of one algorithm).
#[derive(Clone, Debug, Default)]
pub struct Trace {
    /// Algorithm / configuration label ("sI-ADMM M=32", …).
    pub label: String,
    /// Token-codec label (`"q8"`, `"topk+ef"`, …) when the run used a
    /// non-default codec; `None` on the plain-identity path. Gates the
    /// JSON export of the byte columns: the default path serializes
    /// exactly the historical shape, so the blessed golden trace (and
    /// every pre-refactor consumer) sees byte-identical output.
    pub codec: Option<String>,
    /// Kernel-tier label (`"fast"`) when the run used a non-default
    /// tier; `None` on the exact path. Stamped so a byte-compare of a
    /// fast-tier artifact against a blessed exact-tier (golden) trace
    /// fails loudly on this field rather than silently diverging — or
    /// worse, silently matching on shapes too small to reassociate.
    pub kernel: Option<String>,
    /// Membership change points stamped by the dynamic-topology walk
    /// planner (disruption-window shading in figure plots). Empty on a
    /// static schedule — and, like `codec`, gating the JSON export: the
    /// static path serializes exactly the historical shape.
    pub epochs: Vec<crate::topology::EpochMarker>,
    pub points: Vec<TracePoint>,
}

impl Trace {
    /// New empty trace.
    pub fn new(label: &str) -> Self {
        Self { label: label.to_string(), codec: None, kernel: None, epochs: vec![], points: vec![] }
    }

    /// Append a point.
    pub fn push(&mut self, p: TracePoint) {
        self.points.push(p);
    }

    /// Final accuracy (NaN if empty).
    pub fn final_accuracy(&self) -> f64 {
        self.points.last().map(|p| p.accuracy).unwrap_or(f64::NAN)
    }

    /// Final test MSE (NaN if empty).
    pub fn final_test_mse(&self) -> f64 {
        self.points.last().map(|p| p.test_mse).unwrap_or(f64::NAN)
    }

    /// Final simulated running time (NaN if empty) — sweep summaries.
    pub fn final_sim_time(&self) -> f64 {
        self.points.last().map(|p| p.sim_time).unwrap_or(f64::NAN)
    }

    /// Final cumulative communication units, `None` on an empty trace —
    /// sweep summaries. (Previously returned NaN, which silently
    /// poisoned every aggregate it touched; mirroring the `mean_trace`
    /// hardening, the absence of a final point is now explicit and
    /// [`crate::sweep::SweepSummary::from_result`] surfaces it as a
    /// config error.)
    pub fn final_comm_units(&self) -> Option<f64> {
        self.points.last().map(|p| p.comm_units)
    }

    /// Final cumulative wire bytes, `None` on an empty trace — sweep
    /// summaries and the fig7 frontier.
    pub fn final_comm_bytes(&self) -> Option<f64> {
        self.points.last().map(|p| p.comm_bytes)
    }

    /// First iteration at which accuracy drops below `threshold`
    /// (convergence-speed comparisons, Fig. 5).
    pub fn iters_to_accuracy(&self, threshold: f64) -> Option<usize> {
        self.points.iter().find(|p| p.accuracy <= threshold).map(|p| p.iter)
    }

    /// Communication units spent to reach `threshold` accuracy.
    pub fn comm_to_accuracy(&self, threshold: f64) -> Option<f64> {
        self.points.iter().find(|p| p.accuracy <= threshold).map(|p| p.comm_units)
    }

    /// Wire bytes spent to reach `threshold` accuracy (the fig7 /
    /// bytes-to-ε comparisons).
    pub fn bytes_to_accuracy(&self, threshold: f64) -> Option<f64> {
        self.points.iter().find(|p| p.accuracy <= threshold).map(|p| p.comm_bytes)
    }

    /// Simulated time to reach `threshold` accuracy.
    pub fn time_to_accuracy(&self, threshold: f64) -> Option<f64> {
        self.points.iter().find(|p| p.accuracy <= threshold).map(|p| p.sim_time)
    }

    /// Export as a JSON object with parallel arrays (plot-friendly).
    ///
    /// Back-compat contract: on the default identity path
    /// (`codec == None`) the shape — and every byte — of the output is
    /// the historical one (`label` + `iter`/`comm_units`/`sim_time`/
    /// `accuracy`/`test_mse` arrays). Runs under a non-default codec
    /// additionally carry the `codec` label and the `comm_bytes` array.
    pub fn to_json(&self) -> Json {
        let mut b = Json::obj()
            .str("label", &self.label)
            .field("iter", Json::arr_f64(self.points.iter().map(|p| p.iter as f64)))
            .field("comm_units", Json::arr_f64(self.points.iter().map(|p| p.comm_units)))
            .field("sim_time", Json::arr_f64(self.points.iter().map(|p| p.sim_time)))
            .field("accuracy", Json::arr_f64(self.points.iter().map(|p| p.accuracy)))
            .field("test_mse", Json::arr_f64(self.points.iter().map(|p| p.test_mse)));
        if let Some(codec) = &self.codec {
            b = b
                .str("codec", codec)
                .field("comm_bytes", Json::arr_f64(self.points.iter().map(|p| p.comm_bytes)));
        }
        if let Some(kernel) = &self.kernel {
            b = b.str("kernel", kernel);
        }
        if !self.epochs.is_empty() {
            b = b.field(
                "epochs",
                Json::Arr(
                    self.epochs
                        .iter()
                        .map(|e| {
                            Json::obj()
                                .num("iter", e.iter as f64)
                                .num("live", e.live as f64)
                                .num("walk", e.walk as f64)
                                .str("label", &e.label)
                                .build()
                        })
                        .collect(),
                ),
            );
        }
        b.build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(iter: usize, acc: f64) -> TracePoint {
        TracePoint {
            iter,
            comm_units: iter as f64,
            comm_bytes: iter as f64 * 24.0,
            sim_time: iter as f64 * 0.1,
            accuracy: acc,
            test_mse: acc * 2.0,
        }
    }

    #[test]
    fn thresholds() {
        let mut t = Trace::new("x");
        t.push(pt(1, 1.0));
        t.push(pt(10, 0.1));
        t.push(pt(100, 0.01));
        assert_eq!(t.iters_to_accuracy(0.5), Some(10));
        assert_eq!(t.comm_to_accuracy(0.05), Some(100.0));
        assert_eq!(t.bytes_to_accuracy(0.05), Some(2400.0));
        assert_eq!(t.iters_to_accuracy(0.001), None);
        assert!((t.final_accuracy() - 0.01).abs() < 1e-15);
        assert_eq!(t.final_comm_units(), Some(100.0));
        assert_eq!(t.final_comm_bytes(), Some(2400.0));
    }

    /// Regression (PR 5 satellite): the empty trace reports `None`, not
    /// a NaN that poisons sweep aggregates downstream.
    #[test]
    fn empty_trace_has_no_final_comm_units() {
        let t = Trace::new("empty");
        assert_eq!(t.final_comm_units(), None);
        assert_eq!(t.final_comm_bytes(), None);
    }

    #[test]
    fn json_shape() {
        let mut t = Trace::new("sI-ADMM");
        t.push(pt(1, 0.9));
        let s = t.to_json().to_string();
        assert!(s.contains("\"label\":\"sI-ADMM\""));
        assert!(s.contains("\"accuracy\":[0.9]"));
        // Default path: historical shape, no byte columns, no epochs,
        // no kernel stamp.
        assert!(!s.contains("comm_bytes"));
        assert!(!s.contains("codec"));
        assert!(!s.contains("epochs"));
        assert!(!s.contains("kernel"));
    }

    #[test]
    fn json_gains_kernel_stamp_only_off_the_exact_tier() {
        let mut t = Trace::new("sI-ADMM");
        t.push(pt(1, 0.9));
        t.kernel = Some("fast".into());
        let s = t.to_json().to_string();
        assert!(s.contains("\"kernel\":\"fast\""));
    }

    #[test]
    fn json_gains_epoch_markers_only_under_dynamics() {
        let mut t = Trace::new("sI-ADMM");
        t.push(pt(1, 0.9));
        t.epochs.push(crate::topology::EpochMarker {
            iter: 300,
            live: 4,
            walk: 3,
            label: "-2".into(),
        });
        let s = t.to_json().to_string();
        assert!(s.contains("\"epochs\":[{\"iter\":300,\"label\":\"-2\",\"live\":4,\"walk\":3}]"));
    }

    #[test]
    fn json_gains_byte_columns_only_under_a_codec() {
        let mut t = Trace::new("sI-ADMM");
        t.codec = Some("q8".into());
        t.push(pt(1, 0.9));
        let s = t.to_json().to_string();
        assert!(s.contains("\"codec\":\"q8\""));
        assert!(s.contains("\"comm_bytes\":[24]"));
    }
}
