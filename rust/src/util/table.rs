//! ASCII table rendering for bench / CLI output.
//!
//! The bench harness prints the same rows/series the paper reports;
//! criterion is unavailable offline, so the benches are `harness = false`
//! binaries that render with this module.

/// A simple column-aligned ASCII table.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    /// Append a row of pre-formatted cells.
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Append a row of mixed display values.
    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&cells)
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<width$} ", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a float compactly for table cells.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1e4 || x.abs() < 1e-3 {
        format!("{x:.3e}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["alg", "accuracy"]);
        t.row(&["sI-ADMM".into(), "0.001".into()]);
        t.row(&["DGD".into(), "0.1".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("sI-ADMM"));
        let lines: Vec<&str> = r.lines().collect();
        // Header + sep + 2 rows + title
        assert_eq!(lines.len(), 5);
        // Columns aligned: same '|' position in header and data lines.
        let pipe = lines[1].find('|').unwrap();
        assert_eq!(lines[3].find('|').unwrap(), pipe);
        assert_eq!(lines[4].find('|').unwrap(), pipe);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1.5), "1.5000");
        assert!(fnum(1e-7).contains('e'));
        assert!(fnum(5e6).contains('e'));
    }
}
