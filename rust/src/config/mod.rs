//! Experiment configuration: an INI/TOML-subset parser (no `serde`/
//! `toml` offline) plus typed conversion into
//! [`crate::coordinator::RunConfig`].
//!
//! Format: `key = value` lines, `[section]` headers, `#`/`;` comments.
//! Example (`examples/configs/usps.toml` ships with the repo):
//!
//! ```text
//! [run]
//! algo = csiadmm
//! scheme = cyclic
//! dataset = usps
//! n_agents = 10
//! k_ecn = 2
//! s = 1
//! minibatch = 16
//! rho = 0.1
//! max_iters = 4000
//! ```

mod parser;

pub use parser::{ConfigDoc, Value};

use crate::coding::SchemeKind;
use crate::coordinator::{Algorithm, RunConfig, TopologyKind};
use crate::data::DatasetName;
use crate::ecn::ResponseModel;
use crate::error::{Error, Result};
use crate::graph::TraversalKind;
use crate::problem::ObjectiveKind;

/// Apply the optional `[objective]` hyper-parameter section to a parsed
/// objective kind:
///
/// ```text
/// [objective]
/// lambda = 0.01   # logistic ridge weight
/// delta = 1.0     # huber transition point
/// l1 = 0.001      # elastic-net ℓ1 weight
/// l2 = 0.01       # elastic-net ridge weight
/// ```
///
/// Keys that don't apply to the kind are ignored, so one section can
/// parameterize a whole `objective = ls, logistic, huber, enet` sweep
/// axis.
pub fn apply_objective_params(kind: ObjectiveKind, doc: &ConfigDoc) -> ObjectiveKind {
    let sec = "objective";
    match kind {
        ObjectiveKind::Logistic { lambda } => ObjectiveKind::Logistic {
            lambda: doc.get_num(sec, "lambda").unwrap_or(lambda),
        },
        ObjectiveKind::Huber { delta } => ObjectiveKind::Huber {
            delta: doc.get_num(sec, "delta").unwrap_or(delta),
        },
        ObjectiveKind::ElasticNet { l1, l2 } => ObjectiveKind::ElasticNet {
            l1: doc.get_num(sec, "l1").unwrap_or(l1),
            l2: doc.get_num(sec, "l2").unwrap_or(l2),
        },
        ls => ls,
    }
}

/// Parse a run config (and dataset choice) from a config document's
/// `[run]` section, starting from defaults.
pub fn run_config_from_doc(doc: &ConfigDoc) -> Result<(RunConfig, DatasetName)> {
    let mut cfg = RunConfig::default();
    let sec = "run";
    let mut dataset = DatasetName::Synthetic;

    if let Some(v) = doc.get_str(sec, "objective") {
        cfg.objective = ObjectiveKind::parse(&v)
            .ok_or_else(|| Error::Config(format!("unknown objective '{v}'")))?;
    }
    cfg.objective = apply_objective_params(cfg.objective, doc);
    if let Some(v) = doc.get_str(sec, "algo") {
        cfg.algo = match v.as_str() {
            "iadmm" => Algorithm::IAdmmExact,
            "siadmm" => Algorithm::SIAdmm,
            "wadmm" => Algorithm::WAdmm,
            "csiadmm" => {
                let scheme = doc
                    .get_str(sec, "scheme")
                    .and_then(|s| SchemeKind::parse(&s))
                    .unwrap_or(SchemeKind::Cyclic);
                Algorithm::CsIAdmm(scheme)
            }
            other => return Err(Error::Config(format!("unknown algo '{other}'"))),
        };
    }
    if let Some(v) = doc.get_str(sec, "dataset") {
        dataset = DatasetName::parse(&v)
            .ok_or_else(|| Error::Config(format!("unknown dataset '{v}'")))?;
    }
    if let Some(v) = doc.get_str(sec, "traversal") {
        cfg.traversal = match v.as_str() {
            "hamiltonian" => TraversalKind::Hamiltonian,
            "spc" | "shortest-path" => TraversalKind::ShortestPathCycle,
            "random-walk" => TraversalKind::RandomWalk,
            other => return Err(Error::Config(format!("unknown traversal '{other}'"))),
        };
    }
    if let Some(v) = doc.get_str(sec, "topology") {
        cfg.topology = match v.as_str() {
            "random" => TopologyKind::Random,
            "spider" => TopologyKind::Spider,
            other => return Err(Error::Config(format!("unknown topology '{other}'"))),
        };
    }
    macro_rules! set_num {
        ($field:ident, $key:literal, $ty:ty) => {
            if let Some(v) = doc.get_num(sec, $key) {
                cfg.$field = v as $ty;
            }
        };
    }
    set_num!(n_agents, "n_agents", usize);
    set_num!(k_ecn, "k_ecn", usize);
    set_num!(s_tolerated, "s", usize);
    set_num!(minibatch, "minibatch", usize);
    set_num!(rho, "rho", f64);
    set_num!(eta, "eta", f64);
    set_num!(max_iters, "max_iters", usize);
    set_num!(eval_every, "eval_every", usize);
    set_num!(seed, "seed", u64);
    if let Some(v) = doc.get_num(sec, "c_tau") {
        cfg.c_tau = Some(v);
    }
    if let Some(v) = doc.get_num(sec, "c_gamma") {
        cfg.c_gamma = Some(v);
    }
    // Straggler / response model.
    let mut resp = ResponseModel::default();
    if let Some(v) = doc.get_num("stragglers", "count") {
        resp.straggler_count = v as usize;
    }
    if let Some(v) = doc.get_num("stragglers", "delay") {
        resp.straggler_delay = v;
    }
    if let Some(v) = doc.get_num("stragglers", "per_row") {
        resp.per_row = v;
    }
    cfg.response = resp;
    Ok((cfg, dataset))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_round_trip() {
        let text = r#"
# experiment
[run]
algo = csiadmm
scheme = fractional
dataset = usps
n_agents = 8
k_ecn = 4
s = 1
minibatch = 16
rho = 0.25
max_iters = 500
traversal = spc

[stragglers]
count = 1
delay = 0.01
"#;
        let doc = ConfigDoc::parse(text).unwrap();
        let (cfg, ds) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.algo, Algorithm::CsIAdmm(SchemeKind::Fractional));
        assert_eq!(ds, DatasetName::UspsLike);
        assert_eq!(cfg.n_agents, 8);
        assert_eq!(cfg.k_ecn, 4);
        assert_eq!(cfg.s_tolerated, 1);
        assert!((cfg.rho - 0.25).abs() < 1e-12);
        assert_eq!(cfg.traversal, TraversalKind::ShortestPathCycle);
        assert_eq!(cfg.response.straggler_count, 1);
        assert!((cfg.response.straggler_delay - 0.01).abs() < 1e-15);
    }

    #[test]
    fn unknown_algo_rejected() {
        let doc = ConfigDoc::parse("[run]\nalgo = nope\n").unwrap();
        assert!(run_config_from_doc(&doc).is_err());
    }

    #[test]
    fn objective_parsing_with_param_overrides() {
        let doc = ConfigDoc::parse(
            "[run]\nobjective = enet\n\n[objective]\nl1 = 0.05\nl2 = 0.2\n",
        )
        .unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.objective, ObjectiveKind::ElasticNet { l1: 0.05, l2: 0.2 });
        // Defaults survive when the section is absent.
        let doc = ConfigDoc::parse("[run]\nobjective = huber\n").unwrap();
        let (cfg, _) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.objective, ObjectiveKind::Huber { delta: 1.0 });
        // Unknown names error; missing key keeps least squares.
        assert!(run_config_from_doc(&ConfigDoc::parse("[run]\nobjective = nope\n").unwrap())
            .is_err());
        let (cfg, _) = run_config_from_doc(&ConfigDoc::parse("").unwrap()).unwrap();
        assert_eq!(cfg.objective, ObjectiveKind::LeastSquares);
    }

    #[test]
    fn defaults_without_sections() {
        let doc = ConfigDoc::parse("").unwrap();
        let (cfg, ds) = run_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.n_agents, RunConfig::default().n_agents);
        assert_eq!(ds, DatasetName::Synthetic);
    }
}
