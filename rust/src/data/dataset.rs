//! Dataset container.

use crate::linalg::Matrix;

/// Which benchmark dataset (Table I).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetName {
    Synthetic,
    UspsLike,
    Ijcnn1Like,
}

impl DatasetName {
    /// Table I dimensions `(n_train, n_test, p, d)`.
    pub fn dims(&self) -> (usize, usize, usize, usize) {
        match self {
            DatasetName::Synthetic => (50_400, 5_040, 3, 1),
            DatasetName::UspsLike => (1_000, 100, 64, 10),
            DatasetName::Ijcnn1Like => (35_000, 3_500, 22, 2),
        }
    }

    /// Display name used in tables/JSON.
    pub fn as_str(&self) -> &'static str {
        match self {
            DatasetName::Synthetic => "synthetic",
            DatasetName::UspsLike => "usps",
            DatasetName::Ijcnn1Like => "ijcnn1",
        }
    }

    /// Parse from CLI string.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "synthetic" => Some(DatasetName::Synthetic),
            "usps" | "usps-like" => Some(DatasetName::UspsLike),
            "ijcnn1" | "ijcnn1-like" => Some(DatasetName::Ijcnn1Like),
            _ => None,
        }
    }
}

/// One split: inputs `O ∈ R^{n×p}` and targets `T ∈ R^{n×d}`.
#[derive(Clone, Debug)]
pub struct Split {
    pub inputs: Matrix,
    pub targets: Matrix,
}

impl Split {
    /// Number of examples.
    pub fn len(&self) -> usize {
        self.inputs.rows()
    }

    /// True when the split holds no examples.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Row subset by indices.
    pub fn gather(&self, idx: &[usize]) -> Split {
        Split {
            inputs: self.inputs.gather_rows(idx),
            targets: self.targets.gather_rows(idx),
        }
    }

    /// Contiguous row range `[lo, hi)`.
    pub fn slice(&self, lo: usize, hi: usize) -> Split {
        Split {
            inputs: self.inputs.slice_rows(lo, hi),
            targets: self.targets.slice_rows(lo, hi),
        }
    }
}

/// A full dataset: train + test splits and metadata.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: DatasetName,
    pub train: Split,
    pub test: Split,
}

impl Dataset {
    /// Input dimension p.
    pub fn p(&self) -> usize {
        self.train.inputs.cols()
    }

    /// Output dimension d.
    pub fn d(&self) -> usize {
        self.train.targets.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_dims() {
        assert_eq!(DatasetName::Synthetic.dims(), (50_400, 5_040, 3, 1));
        assert_eq!(DatasetName::UspsLike.dims(), (1_000, 100, 64, 10));
        assert_eq!(DatasetName::Ijcnn1Like.dims(), (35_000, 3_500, 22, 2));
    }

    #[test]
    fn parse_names() {
        assert_eq!(DatasetName::parse("usps"), Some(DatasetName::UspsLike));
        assert_eq!(DatasetName::parse("nope"), None);
    }

    #[test]
    fn split_gather_slice() {
        let s = Split {
            inputs: Matrix::from_rows(&[&[0.0], &[1.0], &[2.0], &[3.0]]),
            targets: Matrix::from_rows(&[&[0.0], &[10.0], &[20.0], &[30.0]]),
        };
        let g = s.gather(&[2, 0]);
        assert_eq!(g.inputs.row(0), &[2.0]);
        assert_eq!(g.targets.row(1), &[0.0]);
        let sl = s.slice(1, 3);
        assert_eq!(sl.len(), 2);
        assert_eq!(sl.targets.row(0), &[10.0]);
    }
}
